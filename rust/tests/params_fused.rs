//! Bit-identity twins for the hot-path program (DESIGN.md §14).
//!
//! Every fused, blocked, parallel, or scratch-reusing kernel this PR
//! introduced has a verbatim "before" implementation still in the tree
//! (`params::reference`, the owned decode paths, `Aggregator::combine`).
//! These tests pin the optimization contract: the fast path produces
//! the *same bits* as the path it replaced — not approximately, not
//! within epsilon — across dimensions, worker counts, stale scratch
//! contents, and codec shapes. The artifact-gated finale runs the real
//! server at `--workers ∈ {1, 3}` and diffs curve.csv byte-for-byte.

use fedavg::comms::wire::{
    decode_frame, decode_frame_into, write_dense_frame_into, Frame, Pipeline, Repr,
};
use fedavg::data::rng::Rng;
use fedavg::federated::aggregate::{AggConfig, Aggregator as _};
use fedavg::params::{self, reference, ParamVec};

fn gauss(n: usize, seed: u64) -> Vec<f32> {
    let mut r = Rng::new(seed);
    (0..n).map(|_| r.gauss_f32()).collect()
}

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len()
        && a.iter()
            .map(|v| v.to_bits())
            .eq(b.iter().map(|v| v.to_bits()))
}

/// Client vectors with adversarial float content: negative zeros, huge
/// magnitude spread, denormal-ish tails — anything an op reorder would
/// betray.
fn cohort(m: usize, dim: usize, seed: u64) -> Vec<Vec<f32>> {
    (0..m)
        .map(|i| {
            let mut v = gauss(dim, seed + i as u64);
            for (j, x) in v.iter_mut().enumerate() {
                match (i + j) % 7 {
                    0 => *x = -0.0,
                    1 => *x *= 1e8,
                    2 => *x *= 1e-8,
                    _ => {}
                }
            }
            v
        })
        .collect()
}

// ------------------------------------------------ fused weighted mean

#[test]
fn fused_weighted_mean_matches_reference_bitwise() {
    let mut out = vec![777.0f32; 3]; // stale scratch must not leak through
    for dim in [1usize, 7, 64, 1000, 4097] {
        let vs = cohort(9, dim, 41);
        let items: Vec<(f32, &[f32])> = vs
            .iter()
            .enumerate()
            .map(|(i, v)| (((i % 4) + 1) as f32 * 157.0, v.as_slice()))
            .collect();
        let slow = reference::weighted_mean(&items);
        let fast = params::weighted_mean(&items);
        assert!(bits_eq(&slow, &fast), "fused mean moved a bit at dim={dim}");
        params::weighted_mean_into(&mut out, &items);
        assert!(bits_eq(&slow, &out), "reused buffer moved a bit at dim={dim}");
    }
}

#[test]
fn fused_weighted_mean_normalizes_negative_zero() {
    // the reference zero-fills then accumulates, so -0.0 inputs land as
    // +0.0 (0.0 + s·-0.0); the fused first pass must do the same
    let a = vec![-0.0f32, -0.0, 1.0];
    let b = vec![-0.0f32, 0.0, 2.0];
    let items: Vec<(f32, &[f32])> = vec![(1.0, &a), (3.0, &b)];
    let slow = reference::weighted_mean(&items);
    let fast = params::weighted_mean(&items);
    assert!(bits_eq(&slow, &fast));
    assert_eq!(fast[0].to_bits(), 0.0f32.to_bits(), "-0.0 survived the fold");
}

// ------------------------------------- blocked/parallel order statistics

#[test]
fn parallel_order_stats_match_reference_at_every_worker_count() {
    let mut tm = ParamVec::new();
    let mut md = ParamVec::new();
    for (m, dim) in [(3usize, 63usize), (8, 64), (9, 4097), (20, 10_000)] {
        let vs = cohort(m, dim, 97);
        let refs: Vec<&[f32]> = vs.iter().map(|v| v.as_slice()).collect();
        let tm_ref = reference::trimmed_mean(&refs, 0.2);
        let md_ref = reference::median(&refs);
        for workers in [1usize, 2, 3, 8] {
            params::trimmed_mean_into(&mut tm, &refs, 0.2, workers);
            params::median_into(&mut md, &refs, workers);
            assert!(
                bits_eq(&tm, &tm_ref),
                "trimmed_mean diverged m={m} dim={dim} workers={workers}"
            );
            assert!(
                bits_eq(&md, &md_ref),
                "median diverged m={m} dim={dim} workers={workers}"
            );
        }
    }
}

// --------------------------------------------- zero-copy decode paths

const PIPELINES: &[&str] = &["dense", "q8", "topk:0.02", "topk:0.02|q8", "delta", "delta|q8"];

#[test]
fn borrowed_frame_decode_matches_owned_bitwise() {
    let dim = 5000;
    let base = gauss(dim, 5);
    let x = gauss(dim, 6);
    for spec in PIPELINES {
        let p = Pipeline::parse(spec).unwrap();
        let mut rng = Rng::new(17);
        let frame = p.encode(&x, Some((3, &base)), &mut rng).unwrap();
        let dec_base = p.has_delta().then_some(base.as_slice());
        let owned = frame.decode(dec_base).unwrap();
        // borrowed view into the same bytes, decoded into stale scratch
        let mut buf = vec![-3.5f32; 11];
        frame.view().decode_into(dec_base, &mut buf).unwrap();
        assert!(bits_eq(&owned, &buf), "{spec}: FrameRef decode moved a bit");
        // raw-bytes entry points agree too
        let raw = decode_frame(&frame.bytes, dec_base).unwrap();
        let mut raw_buf = vec![9.0f32; 2];
        decode_frame_into(&frame.bytes, dec_base, &mut raw_buf).unwrap();
        assert!(bits_eq(&owned, &raw), "{spec}: decode_frame diverged");
        assert!(bits_eq(&owned, &raw_buf), "{spec}: decode_frame_into diverged");
    }
}

#[test]
fn repr_decode_into_matches_decode() {
    // the seam Transport::encode_up fuses: the lossy uplink decodes the
    // in-flight Repr into endpoint scratch instead of allocating
    let dim = 4097;
    let x = gauss(dim, 23);
    for spec in ["q8", "topk:0.02", "topk:0.02|q8"] {
        let p = Pipeline::parse(spec).unwrap();
        let mut rng = Rng::new(29);
        let repr = p.run(&x, None, &mut rng).unwrap();
        let owned = repr.decode(None).unwrap();
        let mut buf = vec![f32::NAN; 7];
        repr.decode_into(None, &mut buf).unwrap();
        assert!(bits_eq(&owned, &buf), "{spec}: Repr::decode_into moved a bit");
    }
}

#[test]
fn write_dense_frame_into_matches_to_frame_tagged() {
    // the sharded cascade's reused frame vs the owned construction it
    // replaced — byte-identical, so tier byte accounting is unchanged
    let mut frame = Frame { bytes: Vec::new() };
    for dim in [1usize, 64, 5000] {
        let x = gauss(dim, 31);
        let owned = Repr::dense(&x).to_frame_tagged(1);
        write_dense_frame_into(&x, 1, &mut frame);
        assert_eq!(owned.bytes, frame.bytes, "dim={dim}: reused frame bytes differ");
    }
    // shrinking reuse: a smaller write after a larger one must not keep
    // stale tail bytes
    let x = gauss(3, 37);
    let owned = Repr::dense(&x).to_frame_tagged(1);
    write_dense_frame_into(&x, 1, &mut frame);
    assert_eq!(owned.bytes, frame.bytes, "shrinking reuse left stale bytes");
}

// ------------------------------------------------ aggregator scratch

#[test]
fn combine_into_matches_combine_for_every_registry_rule() {
    let vs = cohort(9, 5000, 67);
    let deltas: Vec<(f32, &[f32])> = vs
        .iter()
        .enumerate()
        .map(|(i, v)| (((i % 3) + 1) as f32 * 211.0, v.as_slice()))
        .collect();
    for spec in ["fedavg", "fedavgm", "fedadam", "trimmed:0.2", "median"] {
        let cfg = AggConfig {
            spec: spec.to_string(),
            ..Default::default()
        };
        let owned = cfg.build().unwrap().combine(&deltas).unwrap();
        for workers in [1usize, 3] {
            let mut agg = cfg.build().unwrap();
            agg.set_workers(workers);
            let mut out = vec![42.0f32; 13]; // stale scratch
            agg.combine_into(&deltas, &mut out).unwrap();
            assert!(
                bits_eq(&owned, &out),
                "{spec}: combine_into diverged at workers={workers}"
            );
        }
    }
}

// --------------------------------------------- artifact-gated (training)

use fedavg::config::{BatchSize, FedConfig, Partition};
use fedavg::coordinator::{FleetConfig, FleetProfile};
use fedavg::federated::{self, ServerOptions};
use fedavg::runtime::Engine;
use fedavg::telemetry::RunWriter;

fn engine() -> Option<Engine> {
    let dir = Engine::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {dir:?} — run `make artifacts`");
        return None;
    }
    Some(Engine::load(dir).expect("engine"))
}

/// The acceptance bar for the whole program: a fleet run through the
/// parallel executor, the fused combine, and the transport scratch at
/// `--workers 3` writes byte-for-byte the curve.csv of the sequential
/// run.
#[test]
fn worker_count_never_moves_a_curve_byte() {
    let Some(eng) = engine() else { return };
    let fed = fedavg::exper::mnist_fed(0.05, Partition::Iid, 73);
    let cfg = FedConfig {
        model: "mnist_2nn".into(),
        c: 0.5,
        e: 1,
        b: BatchSize::Fixed(10),
        lr: 0.1,
        rounds: 3,
        eval_every: 1,
        seed: 73,
        ..Default::default()
    };
    let root = std::path::PathBuf::from(format!(
        "target/test-runs/params-fused-{}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&root).ok();

    let run_at = |workers: usize, name: &str| {
        let w = RunWriter::create(&root, name).unwrap();
        let dir = w.dir().to_path_buf();
        let opts = ServerOptions {
            eval_cap: Some(200),
            telemetry: Some(w),
            agg: AggConfig {
                spec: "trimmed:0.1".into(),
                ..Default::default()
            },
            fleet: FleetConfig {
                profile: FleetProfile::Mobile,
                overselect: 0.3,
                deadline_s: Some(600.0),
                workers,
                ..Default::default()
            },
            ..Default::default()
        };
        let res = federated::run(&eng, &fed, &cfg, opts).unwrap();
        (res, std::fs::read(dir.join("curve.csv")).unwrap())
    };
    let (seq, seq_curve) = run_at(1, "w1");
    let (par, par_curve) = run_at(3, "w3");
    assert_eq!(
        seq.final_theta, par.final_theta,
        "--workers 3 moved final θ vs sequential"
    );
    assert!(
        !seq_curve.is_empty() && seq_curve == par_curve,
        "--workers 3 moved a curve.csv byte vs sequential"
    );
    std::fs::remove_dir_all(&root).ok();
}
