//! Property-based tests on coordinator invariants (offline image: no
//! proptest crate — randomized cases are generated with the in-tree
//! seeded RNG, 100+ cases per property, failures print the case seed).

use fedavg::config::{BatchSize, FedConfig};
use fedavg::data::rng::Rng;
use fedavg::data::{partition, Dataset, Examples};
use fedavg::metrics::LearningCurve;
use fedavg::params;

const CASES: u64 = 120;

fn rand_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.gauss_f32() * scale).collect()
}

// ------------------------------------------------------- params invariants

#[test]
fn prop_weighted_mean_convexity_and_identity() {
    for case in 0..CASES {
        let mut rng = Rng::new(case);
        let dim = 1 + rng.below(200);
        let k = 1 + rng.below(8);
        let vecs: Vec<Vec<f32>> = (0..k).map(|_| rand_vec(&mut rng, dim, 2.0)).collect();
        let ws: Vec<f32> = (0..k).map(|_| 0.5 + rng.f32() * 9.5).collect();
        let items: Vec<(f32, &[f32])> =
            ws.iter().zip(&vecs).map(|(w, v)| (*w, v.as_slice())).collect();
        let mean = params::weighted_mean(&items);
        // convexity: each coordinate within [min, max] of inputs
        for d in 0..dim {
            let lo = vecs.iter().map(|v| v[d]).fold(f32::INFINITY, f32::min);
            let hi = vecs.iter().map(|v| v[d]).fold(f32::NEG_INFINITY, f32::max);
            assert!(
                mean[d] >= lo - 1e-4 && mean[d] <= hi + 1e-4,
                "case {case}: coord {d} out of hull"
            );
        }
        // identity: averaging k copies of the same vector returns it
        let same: Vec<(f32, &[f32])> =
            ws.iter().map(|w| (*w, vecs[0].as_slice())).collect();
        let m2 = params::weighted_mean(&same);
        for d in 0..dim {
            assert!((m2[d] - vecs[0][d]).abs() < 1e-4, "case {case}");
        }
    }
}

#[test]
fn prop_weighted_mean_scale_invariant_in_weights() {
    for case in 0..CASES {
        let mut rng = Rng::new(1000 + case);
        let dim = 1 + rng.below(64);
        let a = rand_vec(&mut rng, dim, 1.0);
        let b = rand_vec(&mut rng, dim, 1.0);
        let (w1, w2) = (1.0 + rng.f32() * 5.0, 1.0 + rng.f32() * 5.0);
        let s = 1.0 + rng.f32() * 99.0;
        let m1 = params::weighted_mean(&[(w1, &a[..]), (w2, &b[..])]);
        let m2 = params::weighted_mean(&[(w1 * s, &a[..]), (w2 * s, &b[..])]);
        for d in 0..dim {
            assert!((m1[d] - m2[d]).abs() < 1e-4, "case {case} coord {d}");
        }
    }
}

#[test]
fn prop_interpolate_linearity() {
    for case in 0..CASES {
        let mut rng = Rng::new(2000 + case);
        let dim = 1 + rng.below(100);
        let a = rand_vec(&mut rng, dim, 3.0);
        let b = rand_vec(&mut rng, dim, 3.0);
        let l = rng.f32() * 1.4 - 0.2; // the Figure-1 range
        let mix = params::interpolate(&a, &b, l);
        for d in 0..dim {
            let want = (1.0 - l) * a[d] + l * b[d];
            assert!((mix[d] - want).abs() < 1e-4, "case {case}");
        }
    }
}

// ------------------------------------------- robust-aggregation invariants

#[test]
fn prop_trimmed_mean_and_median_permutation_invariant() {
    // the aggregate must not depend on the order clients report in
    // (finish order varies with scheduling) — bitwise, thanks to the
    // total_cmp sort inside the kernels
    for case in 0..CASES {
        let mut rng = Rng::new(10_000 + case);
        let dim = 1 + rng.below(100);
        let m = 2 + rng.below(12);
        let vecs: Vec<Vec<f32>> = (0..m).map(|_| rand_vec(&mut rng, dim, 3.0)).collect();
        let frac = rng.f64() * 0.49;
        let mut order: Vec<usize> = (0..m).collect();
        rng.shuffle(&mut order);
        let a: Vec<&[f32]> = vecs.iter().map(|v| v.as_slice()).collect();
        let b: Vec<&[f32]> = order.iter().map(|&i| vecs[i].as_slice()).collect();
        for (x, y) in params::trimmed_mean(&a, frac).iter().zip(&params::trimmed_mean(&b, frac)) {
            assert_eq!(x.to_bits(), y.to_bits(), "case {case}: trimmed not perm-invariant");
        }
        for (x, y) in params::median(&a).iter().zip(&params::median(&b)) {
            assert_eq!(x.to_bits(), y.to_bits(), "case {case}: median not perm-invariant");
        }
    }
}

#[test]
fn prop_trimmed_mean_and_median_bounded_by_client_extremes() {
    for case in 0..CASES {
        let mut rng = Rng::new(11_000 + case);
        let dim = 1 + rng.below(80);
        let m = 1 + rng.below(15);
        let vecs: Vec<Vec<f32>> = (0..m).map(|_| rand_vec(&mut rng, dim, 5.0)).collect();
        let refs: Vec<&[f32]> = vecs.iter().map(|v| v.as_slice()).collect();
        let frac = rng.f64() * 0.49;
        let tm = params::trimmed_mean(&refs, frac);
        let med = params::median(&refs);
        for d in 0..dim {
            let lo = vecs.iter().map(|v| v[d]).fold(f32::INFINITY, f32::min);
            let hi = vecs.iter().map(|v| v[d]).fold(f32::NEG_INFINITY, f32::max);
            for (tag, v) in [("trimmed", tm[d]), ("median", med[d])] {
                assert!(
                    v >= lo - 1e-4 && v <= hi + 1e-4,
                    "case {case} {tag}: coord {d} = {v} outside [{lo}, {hi}]"
                );
            }
        }
    }
}

#[test]
fn prop_trimmed_zero_equals_unweighted_mean() {
    for case in 0..CASES {
        let mut rng = Rng::new(12_000 + case);
        let dim = 1 + rng.below(60);
        let m = 1 + rng.below(10);
        let vecs: Vec<Vec<f32>> = (0..m).map(|_| rand_vec(&mut rng, dim, 2.0)).collect();
        let refs: Vec<&[f32]> = vecs.iter().map(|v| v.as_slice()).collect();
        let tm = params::trimmed_mean(&refs, 0.0);
        let mean = params::mean(&refs);
        for d in 0..dim {
            assert!((tm[d] - mean[d]).abs() < 1e-4, "case {case} coord {d}");
        }
    }
}

// ---------------------------------------------------- partition invariants

#[test]
fn prop_partitions_are_exact() {
    for case in 0..CASES {
        let mut rng = Rng::new(3000 + case);
        let k = 2 + rng.below(30);
        let n = k * (2 + rng.below(50)) + rng.below(k); // any n >= 2k
        for (tag, clients) in [
            ("iid", partition::iid(n, k, &mut rng)),
            ("zipf", partition::unbalanced_zipf(n, k, 1.0 + rng.f64(), &mut rng)),
        ] {
            let mut all: Vec<usize> = clients.iter().flatten().copied().collect();
            all.sort_unstable();
            assert_eq!(
                all,
                (0..n).collect::<Vec<_>>(),
                "case {case} {tag}: not an exact partition (n={n}, k={k})"
            );
            assert!(clients.iter().all(|c| !c.is_empty()), "case {case} {tag}");
        }
    }
}

#[test]
fn prop_pathological_label_concentration() {
    for case in 0..40 {
        let mut rng = Rng::new(4000 + case);
        let classes = 2 + rng.below(12);
        let per_class = 20 + rng.below(40);
        let n = classes * per_class;
        let labels: Vec<i32> = (0..n).map(|i| (i % classes) as i32).collect();
        let k = 2 + rng.below(10);
        let spc = 2;
        if k * spc * 2 > n {
            continue;
        }
        // the paper's regime: shard_size <= examples-per-class, so one
        // shard straddles at most 2 labels (MNIST: shards of 300, 6000
        // per digit). Outside that regime the concentration bound is
        // necessarily weaker, so skip those cases.
        if n / (k * spc) > per_class {
            continue;
        }
        let clients = partition::pathological(&labels, k, spc, &mut rng);
        // each client's label set is tiny relative to the label universe
        for (ci, c) in clients.iter().enumerate() {
            let mut ls: Vec<i32> = c.iter().map(|&i| labels[i]).collect();
            ls.sort_unstable();
            ls.dedup();
            assert!(
                ls.len() <= spc + 2,
                "case {case}: client {ci} sees {} of {classes} labels",
                ls.len()
            );
        }
    }
}

// ------------------------------------------------------ metrics invariants

#[test]
fn prop_monotone_curve_dominates_and_is_monotone() {
    for case in 0..CASES {
        let mut rng = Rng::new(5000 + case);
        let mut curve = LearningCurve::new();
        let mut round = 0u64;
        for _ in 0..(2 + rng.below(40)) {
            round += 1 + rng.below(5) as u64;
            curve.push(round, rng.f64());
        }
        let mono = curve.monotone();
        let mut prev = f64::NEG_INFINITY;
        for (&(r0, raw), &(r1, m)) in curve.points().iter().zip(mono.points()) {
            assert_eq!(r0, r1);
            assert!(m >= raw, "case {case}: monotone below raw");
            assert!(m >= prev, "case {case}: not monotone");
            prev = m;
        }
    }
}

#[test]
fn prop_rounds_to_target_consistent_with_curve() {
    for case in 0..CASES {
        let mut rng = Rng::new(6000 + case);
        let mut curve = LearningCurve::new();
        let mut round = 0u64;
        for _ in 0..(2 + rng.below(30)) {
            round += 1 + rng.below(4) as u64;
            curve.push(round, rng.f64());
        }
        let target = rng.f64();
        let best = curve.best_value().unwrap();
        match curve.rounds_to_target(target) {
            None => assert!(best < target, "case {case}: target reachable but None"),
            Some(r) => {
                assert!(best >= target, "case {case}: unreachable target got Some");
                let (first, _) = curve.points()[0];
                let (last, _) = *curve.points().last().unwrap();
                assert!(
                    r >= first as f64 && r <= last as f64,
                    "case {case}: crossing {r} outside [{first}, {last}]"
                );
            }
        }
    }
}

// ------------------------------------------------------- config invariants

#[test]
fn prop_clients_per_round_bounds() {
    for case in 0..CASES {
        let mut rng = Rng::new(7000 + case);
        let k = 1 + rng.below(5000);
        let cfg = FedConfig {
            c: rng.f64(),
            ..Default::default()
        };
        let m = cfg.clients_per_round(k);
        assert!((1..=k).contains(&m), "case {case}: m={m} k={k} C={}", cfg.c);
    }
}

#[test]
fn prop_updates_per_round_positive_and_scales() {
    for case in 0..CASES {
        let mut rng = Rng::new(8000 + case);
        let e = 1 + rng.below(30);
        let nk = 1 + rng.below(5000);
        let b = 1 + rng.below(nk);
        let u_fixed = fedavg::federated::updates_per_round(e, nk, BatchSize::Fixed(b));
        let u_full = fedavg::federated::updates_per_round(e, nk, BatchSize::Full);
        assert!(u_fixed > 0.0 && u_full > 0.0);
        assert_eq!(u_full, e as f64, "case {case}");
        // B=n_k does exactly E updates; smaller B only does more
        assert!(
            u_fixed >= e as f64 - 1e-9,
            "case {case}: u {u_fixed} < E {e}"
        );
    }
}

// ---------------------------------------------------- staleness invariants
// (async round modes, DESIGN.md §12)

#[test]
fn prop_staleness_weight_monotone_nonincreasing() {
    use fedavg::federated::aggregate::staleness_weight;
    for case in 0..CASES {
        let mut rng = Rng::new(13_000 + case);
        let w = 0.5 + rng.f32() * 20.0;
        let decay = f64::MIN_POSITIVE.max(rng.f64()).min(1.0);
        // fresh deltas are never discounted, whatever the decay
        assert_eq!(staleness_weight(w, decay, 0).to_bits(), w.to_bits(), "case {case}");
        let mut prev = w;
        for s in 1..=40u64 {
            let ws = staleness_weight(w, decay, s);
            assert!(ws.is_finite() && ws >= 0.0, "case {case} s={s}: {ws}");
            assert!(ws <= prev, "case {case}: weight rose at s={s} ({prev} -> {ws})");
            // decay 1.0 is the identity at any staleness
            assert_eq!(staleness_weight(w, 1.0, s).to_bits(), w.to_bits(), "case {case}");
            prev = ws;
        }
    }
}

#[test]
fn prop_staleness_scale_normalizes_partial_buffers() {
    // the scalar applied between combine and step must equal
    // Σ nᵢ·dˢⁱ / Σ nᵢ — so combine(discounted weights) × scale is the
    // discounted sum normalized by the *undiscounted* weight mass, and a
    // buffer of fresh deltas is untouched
    use fedavg::federated::aggregate::{staleness_scale, staleness_weight};
    for case in 0..CASES {
        let mut rng = Rng::new(14_000 + case);
        let k = 1 + rng.below(10);
        let decay = 0.05 + rng.f64() * 0.95;
        let entries: Vec<(f32, u64)> = (0..k)
            .map(|_| (0.5 + rng.f32() * 10.0, rng.below(30) as u64))
            .collect();
        let scale = staleness_scale(&entries, decay);
        assert!((0.0..=1.0 + 1e-12).contains(&scale), "case {case}: scale {scale}");
        let num: f64 = entries
            .iter()
            .map(|&(n, s)| n as f64 * decay.powi(s as i32))
            .sum();
        let den: f64 = entries.iter().map(|&(n, _)| n as f64).sum();
        // the kernel discounts in f32 (the combine's weight type), so
        // allow f32 rounding against the f64 reference
        assert!((scale - num / den).abs() < 1e-5, "case {case}: {scale} vs {}", num / den);
        // all-fresh buffers and decay 1.0 are exactly unscaled
        let fresh: Vec<(f32, u64)> = entries.iter().map(|&(n, _)| (n, 0)).collect();
        assert_eq!(staleness_scale(&fresh, decay), 1.0, "case {case}");
        assert_eq!(staleness_scale(&entries, 1.0), 1.0, "case {case}");
        // consistency with the weighted mean: scale × mean(discounted
        // weights) == Σ nᵢ·dˢⁱ·xᵢ / Σ nᵢ, coordinate-wise
        let dim = 1 + rng.below(20);
        let vecs: Vec<Vec<f32>> = (0..k).map(|_| rand_vec(&mut rng, dim, 2.0)).collect();
        if scale > 0.0 {
            let refs: Vec<(f32, &[f32])> = entries
                .iter()
                .zip(&vecs)
                .map(|(&(n, s), v)| (staleness_weight(n, decay, s), v.as_slice()))
                .collect();
            let mean = params::weighted_mean(&refs);
            for d in 0..dim {
                let want: f64 = entries
                    .iter()
                    .zip(&vecs)
                    .map(|(&(n, s), v)| n as f64 * decay.powi(s as i32) * v[d] as f64)
                    .sum::<f64>()
                    / den;
                let got = mean[d] as f64 * scale;
                assert!(
                    (got - want).abs() < 1e-3,
                    "case {case} coord {d}: {got} vs {want}"
                );
            }
        }
    }
}

#[test]
fn prop_theta_stays_finite_for_any_decay() {
    // a buffered-async run applies scale·combine(...) every drain; for
    // any decay in (0, 1] and any staleness pattern the update must stay
    // finite — tiny decays underflow toward a zero delta, never NaN
    use fedavg::federated::aggregate::{staleness_scale, staleness_weight};
    for case in 0..CASES {
        let mut rng = Rng::new(15_000 + case);
        let dim = 1 + rng.below(40);
        let decay = (rng.f64().powi(4)).max(1e-12).min(1.0); // bias toward tiny
        let mut theta = rand_vec(&mut rng, dim, 1.0);
        for round in 0..12u64 {
            let k = 1 + rng.below(6);
            let entries: Vec<(f32, u64)> = (0..k)
                .map(|_| (0.5 + rng.f32() * 5.0, rng.below(60) as u64))
                .collect();
            let vecs: Vec<Vec<f32>> = (0..k).map(|_| rand_vec(&mut rng, dim, 0.5)).collect();
            let scale = staleness_scale(&entries, decay);
            assert!(scale.is_finite(), "case {case} round {round}");
            let delta = if scale > 0.0 {
                let refs: Vec<(f32, &[f32])> = entries
                    .iter()
                    .zip(&vecs)
                    .map(|(&(n, s), v)| (staleness_weight(n, decay, s), v.as_slice()))
                    .collect();
                let mut d = params::weighted_mean(&refs);
                for v in d.iter_mut() {
                    *v = (*v as f64 * scale) as f32;
                }
                d
            } else {
                vec![0.0f32; dim]
            };
            params::axpy(&mut theta, 1.0, &delta);
            assert!(
                theta.iter().all(|v| v.is_finite()),
                "case {case} round {round}: θ went non-finite (decay {decay})"
            );
        }
    }
}

// ------------------------------------------------------ dataset invariants

#[test]
fn prop_padded_batch_weight_sums() {
    for case in 0..CASES {
        let mut rng = Rng::new(9000 + case);
        let n = 2 + rng.below(60);
        let dim = 1 + rng.below(20);
        let data = Dataset {
            name: "prop".into(),
            examples: Examples::Image {
                x: rand_vec(&mut rng, n * dim, 1.0),
                y: (0..n).map(|_| rng.below(10) as i32).collect(),
                dim,
            },
        };
        let take = 1 + rng.below(n);
        let idxs: Vec<usize> = rng.sample_indices(n, take);
        let cap = take + rng.below(16);
        let b = data.padded_batch(&idxs, cap);
        assert_eq!(b.weight_sum(), take as f64, "case {case}");
        assert_eq!(b.logical, take);
        assert_eq!(data.weight_of(&idxs), take as f64);
    }
}
