//! Checkpoint/resume regression suite (DESIGN.md §8).
//!
//! The core guarantee under test is **bit-identity**: running `2R`
//! rounds produces byte-for-byte the same `curve.csv` as running `R`
//! rounds, checkpointing, and resuming for `R` more. Two engine-free
//! harnesses drive the real stateful subsystems (sampler, transport
//! with top-k error feedback + delta downlink, stateful aggregators,
//! comm simulator, fleet scheduler, DP mechanism) through a synthetic
//! round loop that mirrors `federated::server::run` minus training —
//! so the whole save/restore surface is exercised without artifacts —
//! plus an artifact-gated test over the full training stack. The format
//! tests pin the atomicity/validation contract: torn, corrupt, or
//! mismatched snapshots are rejected whole, never half-loaded.

use std::path::PathBuf;

use fedavg::comms::{CommModel, CommSim, Transport, TransportConfig};
use fedavg::coordinator::{plan_round, Fleet, FleetConfig, FleetProfile, FleetTotals};
use fedavg::data::rng::hash3_unit;
use fedavg::federated::aggregate::{fmt_state_norms, AggConfig, Aggregator};
use fedavg::federated::ClientSampler;
use fedavg::metrics::LearningCurve;
use fedavg::params;
use fedavg::privacy::{clip, GaussianMechanism};
use fedavg::runstate::{
    checkpoint_dir, AggState, AsyncState, BufferedDelta, CurveState, FleetState, ResumeFrom,
    RunMeta, Snapshot, TierState,
};
use fedavg::telemetry::{RoundRecord, RunWriter};

// odd on purpose: an odd dim leaves the DP mechanism's Box–Muller pair
// half-consumed at round end, so the snapshot must carry the cached
// spare deviate for the resume to stay bit-identical
const DIM: usize = 301;
const K: usize = 12;
const M: usize = 4;
const SEED: u64 = 21;

fn test_root(tag: &str) -> PathBuf {
    let root = PathBuf::from(format!(
        "target/test-runs/runstate-{tag}-{}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&root).ok();
    root
}

/// Deterministic stand-in for a client's local update: a function of
/// (round, client, θ) so state errors propagate into every later round.
fn synth_delta(round: u64, client: usize, theta: &[f32]) -> Vec<f32> {
    (0..DIM)
        .map(|i| {
            (hash3_unit(round, client as u64, i as u64) as f32 - 0.5) * 0.1
                - 0.01 * theta[i]
        })
        .collect()
}

/// Fake evaluation: a smooth function of ‖θ‖ (no model involved).
fn fake_eval(theta: &[f32]) -> (f64, f64) {
    let n = params::l2_norm(theta);
    (1.0 / (1.0 + n), n)
}

/// One synthetic run's live state — the same inventory
/// `federated::server::run` snapshots.
struct Harness {
    fleet: Option<Fleet>,
    fleet_cfg: FleetConfig,
    theta: Vec<f32>,
    sampler: ClientSampler,
    transport: Transport,
    comms: CommSim,
    agg: Box<dyn Aggregator>,
    mech: Option<GaussianMechanism>,
    accuracy: LearningCurve,
    test_loss: LearningCurve,
    client_steps: u64,
    fleet_totals: FleetTotals,
    dropped_since_eval: usize,
    misses_since_eval: usize,
    eval_every: u64,
    meta: RunMeta,
}

/// `fleet: true` → mobile device profiles, over-selection + deadline
/// (straggler drops), delta downlink, top-k|q8 uplink, fedavgm.
/// `fleet: false` → legacy jitter path with availability, q8 uplink,
/// fedadam, and DP noise.
fn harness(fleet: bool) -> Harness {
    let fleet_cfg = FleetConfig {
        profile: if fleet { FleetProfile::Mobile } else { FleetProfile::Legacy },
        overselect: 0.5,
        deadline_s: Some(0.5),
        ..FleetConfig::default()
    };
    let transport_cfg = if fleet {
        TransportConfig::parse(Some("topk:30|q8"), Some("delta")).unwrap()
    } else {
        TransportConfig::parse(Some("q8"), None).unwrap()
    };
    let agg_cfg = AggConfig {
        spec: if fleet { "fedavgm:0.8".into() } else { "fedadam:0.01".into() },
        ..Default::default()
    };
    let transport = Transport::new(transport_cfg, K, DIM, SEED);
    let agg = agg_cfg.build().unwrap();
    let mut sampler = ClientSampler::new(SEED);
    if !fleet {
        sampler = sampler.with_availability(0.7, SEED ^ 0xAB1E);
    }
    let meta = RunMeta {
        label: format!("synthetic fleet={fleet}"),
        agg: agg.label(),
        codec: transport.codec_label(),
        seed: SEED,
        clients: K as u64,
        dim: DIM as u64,
        lr_decay: 1.0,
        eval_every: 2,
        harness: format!("fleet={fleet}"),
    };
    Harness {
        fleet: fleet.then(|| Fleet::build(&fleet_cfg, K, SEED)),
        fleet_cfg,
        theta: (0..DIM).map(|i| (i as f32 * 0.01).sin()).collect(),
        sampler,
        transport,
        comms: CommSim::new(CommModel::default(), SEED),
        agg,
        mech: (!fleet).then(|| GaussianMechanism::new(1.0, 0.5, SEED ^ 0xD11F)),
        accuracy: LearningCurve::new(),
        test_loss: LearningCurve::new(),
        client_steps: 0,
        fleet_totals: FleetTotals::default(),
        dropped_since_eval: 0,
        misses_since_eval: 0,
        eval_every: 2,
        meta,
    }
}

impl Harness {
    /// One synchronous round, mirroring the server loop's state flow.
    fn round(&mut self, round: u64, last: u64, w: &mut RunWriter) {
        self.transport.publish(round, &self.theta);
        let est_up = self.transport.up_plan_bytes();
        let mut down_total = 0u64;
        let (picks, round_seconds) = match &self.fleet {
            Some(fl) => {
                let transport = &mut self.transport;
                let theta = &self.theta;
                let (_online, plan) = plan_round(
                    fl,
                    &mut self.sampler,
                    round,
                    M,
                    self.fleet_cfg.overselect,
                    self.fleet_cfg.deadline_s,
                    |c| {
                        let down = transport.downlink(c, round, theta);
                        down_total += down;
                        (down, est_up)
                    },
                    |_| 5.0,
                );
                self.fleet_totals.dispatched += plan.dispatched.len() as u64;
                self.fleet_totals.completed += plan.completed.len() as u64;
                self.fleet_totals.dropped_stragglers += plan.dropped.len() as u64;
                self.fleet_totals.deadline_misses += plan.deadline_miss as u64;
                self.dropped_since_eval += plan.dropped.len();
                self.misses_since_eval += plan.deadline_miss as usize;
                (plan.completed.clone(), plan.round_seconds)
            }
            None => {
                let picks = self.sampler.sample(round, K, M);
                for &c in &picks {
                    down_total += self.transport.downlink(c, round, &self.theta);
                }
                (picks, 0.0)
            }
        };
        let mut wire_up = 0u64;
        let mut deltas: Vec<(f32, Vec<f32>)> = Vec::new();
        for &ck in &picks {
            self.client_steps += 5;
            let mut delta = synth_delta(round, ck, &self.theta);
            if self.mech.is_some() {
                clip(&mut delta, 1.0);
            }
            wire_up += self.transport.encode_up(ck, &mut delta).unwrap();
            deltas.push(((ck % 3 + 1) as f32, delta));
        }
        let refs: Vec<(f32, &[f32])> = deltas.iter().map(|(w, d)| (*w, d.as_slice())).collect();
        let mut agg_delta = self.agg.combine(&refs).unwrap();
        if let Some(mech) = self.mech.as_mut() {
            mech.apply(&mut agg_delta, picks.len());
        }
        let step = self.agg.step(round, agg_delta).unwrap();
        params::axpy(&mut self.theta, 1.0, &step);
        let rc = match &self.fleet {
            Some(_) => self.comms.ingest(wire_up, down_total, round_seconds),
            None => {
                let links: Vec<(u64, u64)> =
                    picks.iter().map(|_| (down_total / picks.len() as u64, est_up)).collect();
                self.comms.round_links(&links)
            }
        };
        if round % self.eval_every == 0 || round == last {
            let (acc, loss) = fake_eval(&self.theta);
            self.accuracy.push(round, acc);
            self.test_loss.push(round, loss);
            let server_state = fmt_state_norms(&self.agg.state_norms());
            w.record(&RoundRecord {
                round,
                test_accuracy: acc,
                test_loss: loss,
                train_loss: None,
                clients: picks.len(),
                lr: 0.1,
                up_bytes: rc.bytes_up,
                down_bytes: rc.bytes_down,
                codec: &self.meta.codec,
                sim_seconds: self.comms.totals().sim_seconds,
                dropped: self.dropped_since_eval,
                deadline_misses: self.misses_since_eval,
                agg: &self.meta.agg,
                server_state: &server_state,
                staleness_mean: 0.0,
                buffer_fill: 0,
            })
            .unwrap();
            self.dropped_since_eval = 0;
            self.misses_since_eval = 0;
        }
    }

    fn snapshot(&self, round: u64) -> Snapshot {
        Snapshot {
            round,
            meta: self.meta.clone(),
            theta: self.theta.clone(),
            client_steps: self.client_steps,
            sampler: self.sampler.state(),
            agg: AggState {
                label: self.agg.label(),
                bytes: self.agg.state_save(),
            },
            transport: self.transport.state_save(),
            comms: self.comms.state_save(),
            fleet: FleetState {
                totals: self.fleet_totals,
                dropped_since_eval: self.dropped_since_eval as u64,
                misses_since_eval: self.misses_since_eval as u64,
            },
            curves: CurveState {
                accuracy: self.accuracy.points().to_vec(),
                test_loss: self.test_loss.points().to_vec(),
                train_loss: None,
            },
            dp: self.mech.as_ref().map(|m| m.state_save()),
            tier: None,
            async_state: None,
        }
    }

    /// The exact restore sequence `federated::server::run` performs.
    fn restore(&mut self, snap: Snapshot) {
        assert_eq!(snap.meta, self.meta, "config fingerprint mismatch");
        self.theta = snap.theta;
        self.sampler.restore_state(snap.sampler);
        assert_eq!(snap.agg.label, self.agg.label());
        self.agg.state_load(&snap.agg.bytes).unwrap();
        self.transport.state_load(snap.transport).unwrap();
        self.comms.state_load(snap.comms);
        if let (Some(m), Some(dp)) = (self.mech.as_mut(), snap.dp) {
            m.state_load(dp);
        }
        self.accuracy = LearningCurve::from_points(snap.curves.accuracy).unwrap();
        self.test_loss = LearningCurve::from_points(snap.curves.test_loss).unwrap();
        self.client_steps = snap.client_steps;
        self.fleet_totals = snap.fleet.totals;
        self.dropped_since_eval = snap.fleet.dropped_since_eval as usize;
        self.misses_since_eval = snap.fleet.misses_since_eval as usize;
    }
}

/// The tentpole regression: `2R` straight vs `R` + checkpoint + resume
/// `R` must produce byte-identical curve.csv files, across a stateful
/// aggregator, a codec with error feedback, and a fleet profile — and
/// the checkpoint round (5) deliberately misses the eval cadence (2) so
/// mid-flight telemetry counters and curve truncation are exercised too.
fn bit_identity_scenario(fleet: bool) {
    let tag = if fleet { "fleet" } else { "legacy" };
    let root = test_root(&format!("bitident-{tag}"));
    let (r1, r2) = (6u64, 12u64);
    let ckpt_round = 5u64;

    // reference: one uninterrupted run of 2R rounds
    let mut full = harness(fleet);
    let mut w = RunWriter::create(&root, "full").unwrap();
    let full_dir = w.dir().to_path_buf();
    for round in 1..=r2 {
        full.round(round, r2, &mut w);
    }
    w.finish(&[("rounds", r2.to_string())]).unwrap();

    // crashed run: R rounds, snapshots every round up to ckpt_round,
    // then rows past the checkpoint are "lost future" to be truncated
    let mut part = harness(fleet);
    let mut w = RunWriter::create(&root, "resumed").unwrap();
    let part_dir = w.dir().to_path_buf();
    let ckpts = checkpoint_dir(&part_dir);
    for round in 1..=r1 {
        part.round(round, r2, &mut w);
        if round <= ckpt_round {
            part.snapshot(round).write(&ckpts, 2).unwrap();
        }
    }
    drop(w); // kill: no finish()

    // keep-last-K rotation: only the newest 2 snapshots remain
    let remaining: Vec<_> = std::fs::read_dir(&ckpts)
        .unwrap()
        .map(|e| e.unwrap().file_name().into_string().unwrap())
        .collect();
    assert_eq!(remaining.len(), 2, "{remaining:?}");

    // resume: newest snapshot, truncate the curve, rerun to 2R
    let (_, snap) = Snapshot::load_latest(&part_dir).unwrap().expect("snapshots exist");
    assert_eq!(snap.round, ckpt_round);
    let mut resumed = harness(fleet);
    resumed.restore(snap);
    let mut w = RunWriter::reopen(&part_dir, ckpt_round).unwrap();
    for round in ckpt_round + 1..=r2 {
        resumed.round(round, r2, &mut w);
    }
    w.finish(&[("rounds", r2.to_string())]).unwrap();

    let a = std::fs::read(full_dir.join("curve.csv")).unwrap();
    let b = std::fs::read(part_dir.join("curve.csv")).unwrap();
    assert!(!a.is_empty() && a == b, "{tag}: resumed curve.csv != uninterrupted curve.csv");
    std::fs::remove_dir_all(root).ok();
}

#[test]
fn resume_bit_identity_fleet_fedavgm_topk() {
    bit_identity_scenario(true);
}

#[test]
fn resume_bit_identity_legacy_fedadam_dp() {
    bit_identity_scenario(false);
}

// ------------------------------------------------------- format contract

/// A snapshot with every section populated (incl. optional DP). `tag`
/// keeps concurrently-running tests out of each other's scratch dirs.
fn rich_snapshot(tag: &str, round: u64) -> Snapshot {
    let mut h = harness(true);
    let root = test_root(&format!("rich-{tag}-{round}"));
    let mut w = RunWriter::create(&root, "scratch").unwrap();
    for r in 1..=round {
        h.round(r, round, &mut w);
    }
    let mut snap = h.snapshot(round);
    snap.dp = Some({
        let mut mech = GaussianMechanism::new(1.0, 0.5, 7);
        let mut v = vec![0.0f32; 7]; // odd: leaves a cached gauss spare
        mech.apply(&mut v, 4);
        mech.state_save()
    });
    snap.curves.train_loss = Some(vec![(2, 1.5), (4, 1.25)]);
    snap.tier = Some(TierState {
        up_bytes: 4 * 1228,
        down_bytes: 3 * 1228,
        frames: 7,
        seconds: 0.875,
    });
    let entry = |r: u64, slot: u64, client: u64, basis: u64, due_s: f64| BufferedDelta {
        dispatch_round: r,
        slot,
        client,
        basis,
        weight: 1.0 + slot as f32,
        due_s,
        delta: (0..DIM).map(|i| (i as f32 * 0.02 + slot as f32).cos()).collect(),
    };
    snap.async_state = Some(AsyncState {
        applies_done: 5,
        late_applied: 2,
        stale_sum_since_eval: 3,
        deltas_since_eval: 9,
        pending: vec![entry(round, 0, 4, 5, 0.0), entry(round, 2, 9, 4, 0.0)],
        late: vec![entry(round.saturating_sub(1), 3, 7, 0, 123.5)],
    });
    std::fs::remove_dir_all(root).ok();
    snap
}

#[test]
fn snapshot_bytes_roundtrip_exactly() {
    for round in [1u64, 3, 6] {
        let snap = rich_snapshot("roundtrip", round);
        let bytes = snap.to_bytes();
        let back = Snapshot::from_bytes(&bytes).unwrap();
        assert_eq!(back, snap, "round {round}: decode(encode(s)) != s");
        // and through the filesystem (atomic write path)
        let root = test_root(&format!("roundtrip-{round}"));
        let dir = checkpoint_dir(&root);
        let path = snap.write(&dir, 3).unwrap();
        assert!(path.file_name().unwrap().to_str().unwrap().ends_with(".bin"));
        assert!(!path.to_str().unwrap().ends_with(".tmp"));
        assert_eq!(Snapshot::read(&path).unwrap(), snap);
        std::fs::remove_dir_all(root).ok();
    }
}

#[test]
fn truncated_snapshots_rejected_at_every_length() {
    let snap = rich_snapshot("trunc", 3);
    let bytes = snap.to_bytes();
    // every strict prefix must be rejected whole — sample densely at the
    // start (header validation) and stride through the payload
    let mut cuts: Vec<usize> = (0..48.min(bytes.len())).collect();
    cuts.extend((48..bytes.len()).step_by(97));
    for cut in cuts {
        assert!(
            Snapshot::from_bytes(&bytes[..cut]).is_err(),
            "truncated snapshot of {cut}/{} bytes loaded",
            bytes.len()
        );
    }
    // trailing garbage is a length mismatch, not silently ignored
    let mut long = bytes.clone();
    long.push(0);
    assert!(Snapshot::from_bytes(&long).is_err());
}

#[test]
fn corrupted_snapshots_rejected() {
    let snap = rich_snapshot("corrupt", 3);
    let bytes = snap.to_bytes();
    // bad magic
    let mut b = bytes.clone();
    b[0] ^= 0xFF;
    assert!(format!("{:#}", Snapshot::from_bytes(&b).unwrap_err()).contains("magic"));
    // unsupported version
    let mut b = bytes.clone();
    b[4] = 99;
    assert!(format!("{:#}", Snapshot::from_bytes(&b).unwrap_err()).contains("version"));
    // payload bit flips → checksum mismatch (stride through the payload)
    for i in (32..bytes.len()).step_by(211) {
        let mut b = bytes.clone();
        b[i] ^= 0x40;
        let err = Snapshot::from_bytes(&b).unwrap_err();
        assert!(
            format!("{err:#}").contains("checksum"),
            "flip at {i}: {err:#}"
        );
    }
    // header round field is covered by the SCHED cross-check
    let mut b = bytes.clone();
    b[8] ^= 0x01;
    assert!(Snapshot::from_bytes(&b).is_err());
}

#[test]
fn load_latest_skips_corrupt_newest_and_reports_none_when_empty() {
    let root = test_root("loadlatest");
    // no checkpoints dir at all
    assert!(Snapshot::load_latest(&root).unwrap().is_none());
    let dir = checkpoint_dir(&root);
    std::fs::create_dir_all(&dir).unwrap();
    // empty dir
    assert!(Snapshot::load_latest(&root).unwrap().is_none());
    // two valid snapshots; newest wins
    rich_snapshot("latest", 2).write(&dir, 5).unwrap();
    let s3 = rich_snapshot("latest", 3);
    let p3 = s3.write(&dir, 5).unwrap();
    let (path, snap) = Snapshot::load_latest(&root).unwrap().unwrap();
    assert_eq!((path, snap.round), (p3.clone(), 3));
    // truncate the newest (torn write survivor): falls back to round 2
    let full = std::fs::read(&p3).unwrap();
    std::fs::write(&p3, &full[..full.len() / 2]).unwrap();
    let (_, snap) = Snapshot::load_latest(&root).unwrap().unwrap();
    assert_eq!(snap.round, 2);
    // every snapshot corrupt → error, not None
    for e in std::fs::read_dir(&dir).unwrap() {
        std::fs::write(e.unwrap().path(), b"FCKPgarbage").unwrap();
    }
    assert!(Snapshot::load_latest(&root).is_err());
    // a stale .tmp from a crash mid-write is never considered
    std::fs::remove_dir_all(&dir).unwrap();
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("ckpt-0000000009.bin.tmp"), b"torn").unwrap();
    assert!(Snapshot::load_latest(&root).unwrap().is_none());
    std::fs::remove_dir_all(root).ok();
}

#[test]
fn restore_rejects_mismatched_configurations() {
    let mut h = harness(true);
    let root = test_root("mismatch");
    let mut w = RunWriter::create(&root, "scratch").unwrap();
    for r in 1..=3 {
        h.round(r, 3, &mut w);
    }
    let snap = h.snapshot(3);
    // wrong aggregator for the recorded state blob
    let mut other = AggConfig {
        spec: "fedavg".into(),
        ..Default::default()
    }
    .build()
    .unwrap();
    assert!(other.state_load(&snap.agg.bytes).is_err());
    // wrong transport shape (client count, dim)
    let cfg = TransportConfig::parse(Some("topk:30|q8"), Some("delta")).unwrap();
    assert!(Transport::new(cfg.clone(), K + 1, DIM, SEED)
        .state_load(snap.transport.clone())
        .is_err());
    assert!(Transport::new(cfg, K, DIM / 2, SEED)
        .state_load(snap.transport.clone())
        .is_err());
    std::fs::remove_dir_all(root).ok();
}

// ------------------------------------------------- ring delta encoding

/// Snapshot format v2: the model-store ring keeps only the newest θ
/// dense; older retained versions ship as overwrite patches against it
/// through the transport's own delta machinery. Reload must be
/// bit-exact, and a ring whose versions differ sparsely must shrink the
/// snapshot substantially versus dense-divergent versions (which take
/// the dense fallback).
#[test]
fn snapshot_ring_delta_is_bit_exact_and_smaller() {
    let dim = 4000usize;
    let base: Vec<f32> = (0..dim).map(|i| (i as f32 * 0.01).sin()).collect();
    let sparse_version = |v: u64, changed: usize| -> Vec<f32> {
        let mut t = base.clone();
        for j in 0..changed {
            t[(j * 37) % dim] = v as f32 + j as f32 * 0.5;
        }
        t
    };

    // sparse ring: old versions differ from the newest on ~1% of coords
    let mut snap = rich_snapshot("ringdelta", 3);
    snap.transport.versions = vec![
        (1, sparse_version(1, 40)),
        (2, sparse_version(2, 60)),
        (3, base.clone()),
    ];
    let sparse_bytes = snap.to_bytes();
    assert_eq!(
        Snapshot::from_bytes(&sparse_bytes).unwrap(),
        snap,
        "delta-encoded ring must reload bit-exactly"
    );

    // dense-divergent ring: every coordinate differs from the newest, so
    // the patch would be *larger* than dense — the fallback must kick in
    // and still roundtrip exactly
    let mut dense_snap = snap.clone();
    dense_snap.transport.versions = vec![
        (1, (0..dim).map(|i| i as f32).collect()),
        (2, (0..dim).map(|i| i as f32 + 0.5).collect()),
        (3, base.clone()),
    ];
    let dense_bytes = dense_snap.to_bytes();
    assert_eq!(Snapshot::from_bytes(&dense_bytes).unwrap(), dense_snap);

    let ratio = sparse_bytes.len() as f64 / dense_bytes.len() as f64;
    println!(
        "snapshot ring delta: sparse ring {} bytes vs dense-divergent {} bytes \
         (size ratio {ratio:.3})",
        sparse_bytes.len(),
        dense_bytes.len()
    );
    assert!(
        ratio < 0.5,
        "sparse ring should shrink the snapshot: ratio {ratio:.3}"
    );

    // degenerate rings: empty and single-version both roundtrip
    let mut s = rich_snapshot("ringdelta-empty", 2);
    s.transport.versions.clear();
    assert_eq!(Snapshot::from_bytes(&s.to_bytes()).unwrap(), s);
    s.transport.versions = vec![(2, base)];
    assert_eq!(Snapshot::from_bytes(&s.to_bytes()).unwrap(), s);
}

// -------------------------------------------------- terminal snapshots

/// A run that completed rounds `1..=R` and wrote its terminal snapshot
/// (DESIGN.md §8) can be *extended* to `2R` without replaying anything:
/// the extended curve is byte-identical to a straight `2R` run. The
/// harness mirrors the server's terminal-snapshot flow engine-free; the
/// artifact-gated test below drives the real server path.
#[test]
fn terminal_snapshot_extends_finished_run() {
    let root = test_root("extend");
    let (r1, r2) = (6u64, 12u64); // even: eval cadence 2 sees no extra rows

    let mut full = harness(true);
    let mut w = RunWriter::create(&root, "full").unwrap();
    let full_dir = w.dir().to_path_buf();
    for round in 1..=r2 {
        full.round(round, r2, &mut w);
    }
    w.finish(&[]).unwrap();

    // the "finished" run: its whole budget was r1 rounds, terminal
    // snapshot written at the final round
    let mut part = harness(true);
    let mut w = RunWriter::create(&root, "extended").unwrap();
    let part_dir = w.dir().to_path_buf();
    for round in 1..=r1 {
        part.round(round, r1, &mut w);
    }
    part.snapshot(r1)
        .write(&checkpoint_dir(&part_dir), 2)
        .unwrap();
    drop(w);

    // extend: resume from the terminal snapshot with a larger budget
    let (_, snap) = Snapshot::load_latest(&part_dir).unwrap().expect("terminal snapshot");
    assert_eq!(snap.round, r1);
    let mut resumed = harness(true);
    resumed.restore(snap);
    let mut w = RunWriter::reopen(&part_dir, r1).unwrap();
    for round in r1 + 1..=r2 {
        resumed.round(round, r2, &mut w);
    }
    w.finish(&[]).unwrap();

    let a = std::fs::read(full_dir.join("curve.csv")).unwrap();
    let b = std::fs::read(part_dir.join("curve.csv")).unwrap();
    assert!(!a.is_empty() && a == b, "extended curve.csv != straight-run curve.csv");
    std::fs::remove_dir_all(root).ok();
}

/// The server writes the terminal snapshot even when the cadence never
/// fires, and `--resume` with a larger `--rounds` continues bit-exactly
/// (artifact-gated).
#[test]
fn server_terminal_checkpoint_extends_over_artifacts() {
    use fedavg::config::{BatchSize, FedConfig, Partition};
    use fedavg::federated::{self, ServerOptions};
    use fedavg::runstate::CheckpointConfig;
    use fedavg::runtime::Engine;

    let dir = Engine::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {dir:?} — run `make artifacts`");
        return;
    }
    let eng = Engine::load(dir).expect("engine");
    let fed = fedavg::exper::mnist_fed(0.05, Partition::Iid, 51);
    let cfg = |rounds| FedConfig {
        model: "mnist_2nn".into(),
        c: 0.3,
        e: 1,
        b: BatchSize::Fixed(10),
        lr: 0.1,
        rounds,
        eval_every: 1,
        seed: 51,
        ..Default::default()
    };
    let opts = |telemetry: Option<RunWriter>| ServerOptions {
        eval_cap: Some(200),
        telemetry,
        ..Default::default()
    };
    let root = test_root("server-extend");

    let w = RunWriter::create(&root, "full").unwrap();
    let full_dir = w.dir().to_path_buf();
    let full = federated::run(&eng, &fed, &cfg(6), opts(Some(w))).unwrap();

    // cadence 100 never fires in 3 rounds — only the terminal snapshot
    let w = RunWriter::create(&root, "extended").unwrap();
    let part_dir = w.dir().to_path_buf();
    let mut o = opts(Some(w));
    o.checkpoint = Some(CheckpointConfig { every: 100, keep: 2 });
    federated::run(&eng, &fed, &cfg(3), o).unwrap();
    let (_, snap) = Snapshot::load_latest(&part_dir)
        .unwrap()
        .expect("terminal snapshot written off-cadence");
    assert_eq!(snap.round, 3);

    let mut o = opts(None);
    o.checkpoint = Some(CheckpointConfig { every: 100, keep: 2 });
    o.resume = Some(ResumeFrom {
        snapshot: snap,
        run_dir: part_dir.clone(),
    });
    let resumed = federated::run(&eng, &fed, &cfg(6), o).unwrap();

    assert_eq!(full.final_theta, resumed.final_theta, "extension diverged");
    let a = std::fs::read(full_dir.join("curve.csv")).unwrap();
    let b = std::fs::read(part_dir.join("curve.csv")).unwrap();
    assert_eq!(a, b, "extended curve.csv != straight-run curve.csv");
    std::fs::remove_dir_all(root).ok();
}

// ------------------------------------- full-stack (artifact-gated) test

#[test]
fn server_resume_bit_identity_over_artifacts() {
    use fedavg::config::{BatchSize, FedConfig, Partition};
    use fedavg::federated::{self, ServerOptions};
    use fedavg::runstate::CheckpointConfig;
    use fedavg::runtime::Engine;

    let dir = Engine::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {dir:?} — run `make artifacts`");
        return;
    }
    let eng = Engine::load(dir).expect("engine");
    let fed = fedavg::exper::mnist_fed(0.05, Partition::Iid, 40);
    let cfg = |rounds| FedConfig {
        model: "mnist_2nn".into(),
        c: 0.3,
        e: 1,
        b: BatchSize::Fixed(10),
        lr: 0.1,
        rounds,
        eval_every: 1,
        seed: 40,
        ..Default::default()
    };
    let opts = |telemetry: Option<RunWriter>| ServerOptions {
        eval_cap: Some(200),
        telemetry,
        transport: TransportConfig::parse(Some("topk:0.02|q8"), Some("delta")).unwrap(),
        agg: AggConfig {
            spec: "fedavgm:0.9".into(),
            ..Default::default()
        },
        fleet: FleetConfig {
            profile: FleetProfile::Mobile,
            overselect: 0.3,
            ..FleetConfig::default()
        },
        ..Default::default()
    };
    let root = test_root("server");

    // uninterrupted 6-round reference
    let w = RunWriter::create(&root, "full").unwrap();
    let full_dir = w.dir().to_path_buf();
    let full = federated::run(&eng, &fed, &cfg(6), opts(Some(w))).unwrap();

    // 3 rounds with checkpointing, then resume to 6
    let w = RunWriter::create(&root, "resumed").unwrap();
    let part_dir = w.dir().to_path_buf();
    let mut o = opts(Some(w));
    o.checkpoint = Some(CheckpointConfig { every: 3, keep: 2 });
    federated::run(&eng, &fed, &cfg(3), o).unwrap();
    let (_, snap) = Snapshot::load_latest(&part_dir).unwrap().expect("checkpoint written");
    assert_eq!(snap.round, 3);
    let mut o = opts(None);
    o.resume = Some(ResumeFrom {
        snapshot: snap,
        run_dir: part_dir.clone(),
    });
    let resumed = federated::run(&eng, &fed, &cfg(6), o).unwrap();

    assert_eq!(full.final_theta, resumed.final_theta, "trajectory diverged");
    assert_eq!(full.accuracy.points(), resumed.accuracy.points());
    assert_eq!(full.comm.bytes_up, resumed.comm.bytes_up);
    assert_eq!(full.comm.bytes_down, resumed.comm.bytes_down);
    let a = std::fs::read(full_dir.join("curve.csv")).unwrap();
    let b = std::fs::read(part_dir.join("curve.csv")).unwrap();
    assert_eq!(a, b, "resumed curve.csv != uninterrupted curve.csv");

    // a mismatched configuration must be refused — and the refusal must
    // leave the run dir's telemetry byte-identical (no truncation)
    let (_, snap) = Snapshot::load_latest(&part_dir).unwrap().unwrap();
    let before = std::fs::read(part_dir.join("curve.csv")).unwrap();
    let mut o = opts(None);
    o.agg.spec = "fedavg".into(); // different rule than the checkpoint
    o.resume = Some(ResumeFrom {
        snapshot: snap,
        run_dir: part_dir.clone(),
    });
    assert!(federated::run(&eng, &fed, &cfg(6), o).is_err());
    assert_eq!(
        before,
        std::fs::read(part_dir.join("curve.csv")).unwrap(),
        "a refused resume truncated the original run's curve"
    );
    std::fs::remove_dir_all(root).ok();
}
