//! Integration: rust PJRT runtime executing the real AOT artifacts.
//!
//! These tests require `make artifacts` to have run (skipped with a clear
//! message otherwise). They verify the rust-side view of the L2 entry
//! contract using *native* invariants (determinism, axpy identity, grad
//! linearity, chunked-full-batch equivalence) — no python in the loop.

use fedavg::data::{Dataset, Examples};
use fedavg::runtime::Engine;

fn engine() -> Option<Engine> {
    let dir = Engine::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {dir:?} — run `make artifacts`");
        return None;
    }
    Some(Engine::load(dir).expect("engine"))
}

fn toy_mnist(n: usize, seed: u64) -> Dataset {
    let mut rng = fedavg::data::rng::Rng::new(seed);
    let x: Vec<f32> = (0..n * 784).map(|_| rng.gauss_f32() * 0.5).collect();
    let y: Vec<i32> = (0..n).map(|_| rng.below(10) as i32).collect();
    Dataset {
        name: "toy".into(),
        examples: Examples::Image { x, y, dim: 784 },
    }
}

#[test]
fn init_is_deterministic_and_seed_sensitive() {
    let Some(eng) = engine() else { return };
    let model = eng.model("mnist_2nn").unwrap();
    let a = model.init(7).unwrap();
    let b = model.init(7).unwrap();
    let c = model.init(8).unwrap();
    assert_eq!(a.len(), 199_210);
    assert_eq!(a, b, "same seed must give identical params");
    assert_ne!(a, c, "different seeds must differ");
    let norm = fedavg::params::l2_norm(&a);
    assert!(norm > 1.0 && norm < 100.0, "init norm {norm}");
}

#[test]
fn apply_matches_native_axpy() {
    let Some(eng) = engine() else { return };
    let model = eng.model("mnist_2nn").unwrap();
    let theta = model.init(1).unwrap();
    let g: Vec<f32> = (0..theta.len()).map(|i| ((i % 13) as f32 - 6.0) * 0.01).collect();
    let out = model.apply(&theta, &g, 0.25).unwrap();
    for i in (0..theta.len()).step_by(9973) {
        let want = theta[i] - 0.25 * g[i];
        assert!(
            (out[i] - want).abs() < 1e-6,
            "apply[{i}]: {} vs {want}",
            out[i]
        );
    }
}

#[test]
fn step_changes_params_and_respects_lr_zero() {
    let Some(eng) = engine() else { return };
    let model = eng.model("mnist_2nn").unwrap();
    let theta = model.init(2).unwrap();
    let data = toy_mnist(10, 3);
    let idxs: Vec<usize> = (0..10).collect();
    let batch = data.padded_batch(&idxs, 10);

    let frozen = model.step(&theta, &batch, 0.0).unwrap();
    assert_eq!(frozen, theta, "lr=0 step must be identity");

    let moved = model.step(&theta, &batch, 0.1).unwrap();
    let dist = fedavg::params::l2_dist(&theta, &moved);
    assert!(dist > 1e-4, "lr=0.1 step moved {dist}");
}

#[test]
fn gradacc_is_linear_in_examples() {
    let Some(eng) = engine() else { return };
    let model = eng.model("mnist_2nn").unwrap();
    let theta = model.init(4).unwrap();
    let data = toy_mnist(64, 5);
    let all: Vec<usize> = (0..64).collect();
    let full = model.gradacc(&theta, &data.padded_batch(&all, 64)).unwrap();
    let a = model
        .gradacc(&theta, &data.padded_batch(&all[..32], 64))
        .unwrap();
    let b = model
        .gradacc(&theta, &data.padded_batch(&all[32..], 64))
        .unwrap();
    let mut sum = a;
    fedavg::params::axpy(&mut sum, 1.0, &b);
    let err = full
        .iter()
        .zip(&sum)
        .map(|(x, y)| (x - y).abs() as f64)
        .fold(0.0, f64::max);
    let scale = fedavg::params::l2_norm(&full) / (full.len() as f64).sqrt();
    assert!(err < 1e-4 + 1e-3 * scale, "linearity violated: {err}");
}

#[test]
fn chunked_full_batch_equals_direct_step() {
    // the B=inf path: gradacc chunks + apply == step over the same batch
    let Some(eng) = engine() else { return };
    let model = eng.model("mnist_2nn").unwrap();
    let theta = model.init(6).unwrap();
    let data = toy_mnist(50, 7);
    let idxs: Vec<usize> = (0..50).collect();
    let lr = 0.2f32;

    let direct = model
        .step(&theta, &data.padded_batch(&idxs, 50), lr)
        .unwrap();

    let (g, wsum) = model.full_gradient(&theta, &data, &idxs).unwrap();
    assert!((wsum - 50.0).abs() < 1e-9);
    let via_chunks = model.apply(&theta, &g, lr).unwrap();

    let dist = fedavg::params::l2_dist(&direct, &via_chunks);
    let base = fedavg::params::l2_norm(&direct);
    assert!(dist / base < 1e-5, "chunked vs direct: rel {}", dist / base);
}

#[test]
fn eval_reports_sane_random_init_metrics() {
    let Some(eng) = engine() else { return };
    let model = eng.model("mnist_2nn").unwrap();
    let theta = model.init(9).unwrap();
    let data = toy_mnist(200, 11);
    let sums = model.eval_dataset(&theta, &data, None).unwrap();
    assert!((sums.weight_sum - 200.0).abs() < 1e-6);
    // random 10-class task at random init: loss ~ ln 10, acc ~ 0.1
    assert!(sums.mean_loss() > 1.5 && sums.mean_loss() < 4.0, "{}", sums.mean_loss());
    assert!(sums.accuracy() < 0.5, "{}", sums.accuracy());
}

#[test]
fn training_reduces_loss_on_toy_data() {
    let Some(eng) = engine() else { return };
    let model = eng.model("mnist_2nn").unwrap();
    let mut theta = model.init(10).unwrap();
    let data = toy_mnist(60, 13);
    let idxs: Vec<usize> = (0..60).collect();
    let before = model.eval_dataset(&theta, &data, None).unwrap().mean_loss();
    let mut rng = fedavg::data::rng::Rng::new(99);
    let mut order = idxs.clone();
    for _epoch in 0..8 {
        rng.shuffle(&mut order);
        for chunk in order.chunks(10) {
            let b = data.padded_batch(chunk, 10);
            theta = model.step(&theta, &b, 0.1).unwrap();
        }
    }
    let after = model.eval_dataset(&theta, &data, None).unwrap().mean_loss();
    assert!(
        after < 0.6 * before,
        "loss did not drop: {before} -> {after}"
    );
}

#[test]
fn token_model_eval_and_step_run() {
    let Some(eng) = engine() else { return };
    let model = eng.model("shakespeare_lstm").unwrap();
    let meta = model.meta().clone();
    assert!(meta.is_tokens());
    let t = meta.x_dim;
    let mut rng = fedavg::data::rng::Rng::new(21);
    let n = 12;
    let mut x = vec![0i32; n * t];
    let mut y = vec![0i32; n * t];
    let mut w = vec![0.0f32; n * t];
    for r in 0..n {
        let len = 20 + rng.below(t - 20);
        for i in 0..len {
            x[r * t + i] = rng.below(90) as i32;
            y[r * t + i] = rng.below(90) as i32;
            w[r * t + i] = 1.0;
        }
    }
    let data = Dataset {
        name: "toy-tokens".into(),
        examples: Examples::Tokens { x, y, w, t },
    };
    let theta = model.init(3).unwrap();
    let sums = model
        .eval_dataset(&theta, &data, None)
        .unwrap();
    assert!(sums.weight_sum > 0.0);
    // ~uniform over 90 chars -> loss near ln(90) ≈ 4.5
    assert!(sums.mean_loss() > 3.0 && sums.mean_loss() < 6.0, "{}", sums.mean_loss());
    let idxs: Vec<usize> = (0..n).collect();
    let b = data.padded_batch(&idxs[..10], 10);
    let theta2 = model.step(&theta, &b, 0.5).unwrap();
    assert_ne!(theta, theta2);
}

#[test]
fn worker_pool_runs_client_updates_with_per_thread_engines() {
    // Algorithm 1's "in parallel": each worker thread owns its own PJRT
    // engine (the xla types are not Send); jobs are (client, theta) pairs.
    if !Engine::default_dir().join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    use fedavg::config::BatchSize;
    use fedavg::federated::{local_update, LocalSpec};
    use fedavg::runtime::pool::WorkerPool;
    use std::sync::Arc;

    let data = Arc::new(toy_mnist(40, 31));
    let theta0 = {
        let eng = Engine::load(Engine::default_dir()).unwrap();
        Arc::new(eng.model("mnist_2nn").unwrap().init(5).unwrap())
    };

    type Job = (usize, Vec<usize>);
    type Out = (usize, Vec<f32>, f64);
    let data2 = data.clone();
    let theta2 = theta0.clone();
    let pool: WorkerPool<Job, Out> = WorkerPool::new(
        2,
        move |_id| {
            let eng = Engine::load(Engine::default_dir())?;
            eng.warmup("mnist_2nn", &["step_b10"])?;
            Ok(eng)
        },
        move |eng, (client, idxs): Job| {
            let model = eng.model("mnist_2nn").unwrap();
            let spec = LocalSpec {
                epochs: 1,
                batch: BatchSize::Fixed(10),
                lr: 0.05,
                prox_mu: 0.0,
                shuffle_seed: client as u64,
            };
            let res = local_update(&model, &data2, &idxs, &theta2, &spec).unwrap();
            (client, res.theta, res.weight)
        },
    )
    .unwrap();

    let jobs: Vec<Job> = (0..4)
        .map(|c| (c, (c * 10..(c + 1) * 10).collect()))
        .collect();
    let mut outs = pool.map(jobs).unwrap();
    outs.sort_by_key(|(c, _, _)| *c);
    assert_eq!(outs.len(), 4);
    for (c, theta, w) in &outs {
        assert_eq!(*w, 10.0, "client {c}");
        assert_ne!(theta, theta0.as_ref(), "client {c} did not train");
    }
    // deterministic per client: two pool runs give identical results —
    // exercised implicitly by seeding; check clients differ from each other
    assert_ne!(outs[0].1, outs[1].1);
}
