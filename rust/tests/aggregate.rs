//! Aggregation-subsystem invariants (artifact-free).
//!
//! The load-bearing guarantee: the default [`AggConfig`] (`fedavg`,
//! η_s = 1) reproduces the pre-subsystem server update — an inlined
//! `params::weighted_mean` followed by `axpy(θ, 1.0, Δ̄)` — **bit for
//! bit**, so extracting the rule behind the `Aggregator` trait changed
//! no trajectory and no byte accounting. Plus the rule-specific maths:
//! momentum/Adam recurrences, robust rules ignoring weights and killing
//! outliers, and FedProx's proximal step.

use fedavg::data::rng::Rng;
use fedavg::federated::aggregate::{AggConfig, Aggregator as _};
use fedavg::params;

fn rand_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.gauss_f32() * scale).collect()
}

/// The seed's inlined server update, verbatim: weighted mean + axpy.
fn legacy_update(theta: &mut [f32], deltas: &[(f32, &[f32])]) {
    let avg = params::weighted_mean(deltas);
    params::axpy(theta, 1.0, &avg);
}

#[test]
fn default_aggconfig_is_bit_identical_to_the_legacy_update() {
    for case in 0..50u64 {
        let mut rng = Rng::new(case);
        let dim = 1 + rng.below(500);
        let m = 1 + rng.below(12);
        let vecs: Vec<Vec<f32>> = (0..m).map(|_| rand_vec(&mut rng, dim, 1.5)).collect();
        let ws: Vec<f32> = (0..m).map(|_| 1.0 + rng.f32() * 600.0).collect();
        let deltas: Vec<(f32, &[f32])> =
            ws.iter().zip(&vecs).map(|(w, v)| (*w, v.as_slice())).collect();
        let mut theta_legacy = rand_vec(&mut rng, dim, 1.0);
        let mut theta_new = theta_legacy.clone();

        legacy_update(&mut theta_legacy, &deltas);

        let mut agg = AggConfig::default().build().unwrap();
        assert_eq!(agg.label(), "fedavg");
        let combined = agg.combine(&deltas).unwrap();
        let step = agg.step(case, combined).unwrap();
        params::axpy(&mut theta_new, 1.0, &step);

        for (i, (a, b)) in theta_legacy.iter().zip(&theta_new).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "case {case}: coord {i} diverged from the legacy update"
            );
        }
        assert!(agg.state_norms().is_empty(), "fedavg must stay stateless");
    }
}

#[test]
fn fedavg_server_lr_scales_the_step() {
    let cfg = AggConfig {
        server_lr: Some(0.5),
        ..Default::default()
    };
    let mut agg = cfg.build().unwrap();
    let d = vec![2.0f32, -4.0, 0.0];
    let step = agg.step(1, d).unwrap();
    assert_eq!(step, vec![1.0, -2.0, 0.0]);
}

#[test]
fn fedadam_unset_server_lr_resolves_to_adam_scaled_default() {
    // unset η_s is per-rule: 1.0 for the mean/robust rules, 0.01 for
    // fedadam (whose step is ~±η_s per coordinate once u warms up) — so
    // the plain CLI `--agg fedadam` trains instead of diverging
    let mut adam = AggConfig {
        spec: "fedadam".into(),
        ..Default::default()
    }
    .build()
    .unwrap();
    // m = 0.1, u = 0.01·1, step = η_s·0.1/(0.1 + 0.001) ≈ 0.99·η_s
    let s = adam.step(1, vec![1.0f32]).unwrap();
    assert!(
        s[0] > 0.005 && s[0] < 0.05,
        "η_s default not Adam-scaled: step {}",
        s[0]
    );
    // the mean rules keep the bit-identical η_s = 1 default
    let mut avg = AggConfig::default().build().unwrap();
    let d = vec![0.25f32, -1.5];
    assert_eq!(avg.step(1, d.clone()).unwrap(), d);
}

#[test]
fn fedavgm_momentum_recurrence() {
    // v_t = β·v_{t-1} + Δ̄_t ; step = η_s·v_t — checked over two rounds
    let cfg = AggConfig {
        spec: "fedavgm:0.5".into(),
        server_lr: Some(2.0),
        ..Default::default()
    };
    let mut agg = cfg.build().unwrap();
    assert_eq!(agg.label(), "fedavgm:0.5");
    assert!(agg.state_norms().is_empty(), "no state before the first step");

    // round 1: v = d1, step = 2·d1
    let s1 = agg.step(1, vec![1.0, -2.0]).unwrap();
    assert_eq!(s1, vec![2.0, -4.0]);
    // round 2: v = 0.5·d1 + d2 = [0.5+3, -1+1] = [3.5, 0.0], step = 2·v
    let s2 = agg.step(2, vec![3.0, 1.0]).unwrap();
    assert_eq!(s2, vec![7.0, 0.0]);

    let norms = agg.state_norms();
    assert_eq!(norms.len(), 1);
    assert_eq!(norms[0].0, "momentum");
    assert!((norms[0].1 - 3.5).abs() < 1e-6, "‖v‖ = {}", norms[0].1);
}

#[test]
fn fedadam_moment_recurrence() {
    // m = β1·m + (1-β1)·d ; u = β2·u + (1-β2)·d² ; step = η·m/(√u + τ)
    let cfg = AggConfig {
        spec: "fedadam:0.1".into(), // τ = 0.1 for easy arithmetic
        server_lr: Some(1.0),
        server_momentum: 0.5, // β1
        ..Default::default()
    };
    let mut agg = cfg.build().unwrap();
    assert_eq!(agg.label(), "fedadam:0.1");
    let s1 = agg.step(1, vec![1.0f32]).unwrap();
    // m = 0.5, u = 0.01·1 = 0.01, step = 0.5/(0.1 + 0.1) = 2.5
    assert!((s1[0] - 2.5).abs() < 1e-5, "{}", s1[0]);
    let norms = agg.state_norms();
    assert_eq!(norms.len(), 2);
    assert_eq!((norms[0].0, norms[1].0), ("m", "u"));
    assert!((norms[0].1 - 0.5).abs() < 1e-6);
    assert!((norms[1].1 - 0.01).abs() < 1e-7);
    // adaptivity: a second identical delta grows u, shrinking nothing
    // catastrophically — step stays finite and sign-correct
    let s2 = agg.step(2, vec![1.0f32]).unwrap();
    assert!(s2[0].is_finite() && s2[0] > 0.0);
}

#[test]
fn robust_rules_ignore_weights_and_survive_a_byzantine_client() {
    // 9 honest clients report Δ = 1 per coordinate; one Byzantine client
    // reports 1e6 with a huge claimed n_k. FedAvg is destroyed; the
    // robust order statistics are untouched.
    let honest = vec![1.0f32; 4];
    let evil = vec![1e6f32; 4];
    let mut deltas: Vec<(f32, &[f32])> = (0..9).map(|_| (1.0, honest.as_slice())).collect();
    deltas.push((1000.0, evil.as_slice()));

    let fedavg = AggConfig::default().build().unwrap();
    let broken = fedavg.combine(&deltas).unwrap();
    assert!(broken[0] > 1e5, "weighted mean should be dominated: {}", broken[0]);

    for spec in ["trimmed:0.1", "median"] {
        let agg = AggConfig {
            spec: spec.into(),
            ..Default::default()
        }
        .build()
        .unwrap();
        let robust = agg.combine(&deltas).unwrap();
        for (j, v) in robust.iter().enumerate() {
            assert_eq!(*v, 1.0, "{spec}: coord {j} moved by the Byzantine client");
        }
    }
}

#[test]
fn robust_rules_tolerate_variable_cohort_size() {
    // straggler drops shrink m round to round; the trim must re-derive
    // from the realized cohort and never empty it
    let agg = AggConfig {
        spec: "trimmed:0.4".into(),
        ..Default::default()
    }
    .build()
    .unwrap();
    for m in 1..=7 {
        let vecs: Vec<Vec<f32>> = (0..m).map(|i| vec![i as f32]).collect();
        let deltas: Vec<(f32, &[f32])> = vecs.iter().map(|v| (1.0, v.as_slice())).collect();
        let out = agg.combine(&deltas).unwrap();
        assert!(out[0].is_finite(), "m={m}");
        assert!(out[0] >= 0.0 && out[0] <= (m - 1) as f32, "m={m}: {}", out[0]);
    }
}
