//! Hierarchical sharded aggregation — shard↔flat bit-identity suite
//! (DESIGN.md §11).
//!
//! The tentpole guarantee under test: `--shards S` routes every round's
//! combine through S edge aggregators and a root cascade, and the run is
//! **byte-for-byte identical** to flat aggregation — same θ trajectory,
//! same `curve.csv` — for every mean-family rule, any shard count, and
//! any worker count. Tier-1 (edge↔root) bytes and latency land only in
//! the tier accounting (`tiers.csv`, `tier.*` metrics, summary fields,
//! snapshot `TIER` section), never in the curve. An engine-free harness
//! (mirroring `rust/tests/runstate.rs`) drives the real subsystems —
//! sampler, transport with error feedback, stateful aggregators, comm
//! simulator, the sharded cascade itself — through a synthetic round
//! loop; artifact-gated tests repeat the identity over the full training
//! stack. Robust rules (`trimmed:<β>`, `median`) must refuse to shard:
//! coordinate-wise order statistics do not compose across tiers.

use std::path::PathBuf;

use fedavg::comms::wire::HEADER_BYTES;
use fedavg::comms::{CommModel, CommSim, Transport, TransportConfig};
use fedavg::coordinator::{tier_transfer_seconds, FleetTotals, TierLink};
use fedavg::data::rng::hash3_unit;
use fedavg::federated::aggregate::{combine_sharded, fmt_state_norms, AggConfig, Aggregator};
use fedavg::federated::ClientSampler;
use fedavg::metrics::LearningCurve;
use fedavg::params;
use fedavg::runstate::{
    checkpoint_dir, AggState, CurveState, FleetState, RunMeta, Snapshot, TierState,
};
use fedavg::telemetry::{RoundRecord, RunWriter};

const DIM: usize = 301;
const K: usize = 12;
const M: usize = 4;
const SEED: u64 = 21;

fn test_root(tag: &str) -> PathBuf {
    let root = PathBuf::from(format!(
        "target/test-runs/shards-{tag}-{}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&root).ok();
    root
}

/// Deterministic stand-in for a client's local update (same recipe as
/// `rust/tests/runstate.rs`): a function of (round, client, θ) so a
/// single wrong bit in the combine propagates into every later round.
fn synth_delta(round: u64, client: usize, theta: &[f32]) -> Vec<f32> {
    (0..DIM)
        .map(|i| {
            (hash3_unit(round, client as u64, i as u64) as f32 - 0.5) * 0.1
                - 0.01 * theta[i]
        })
        .collect()
}

/// Fake evaluation: a smooth function of ‖θ‖ (no model involved).
fn fake_eval(theta: &[f32]) -> (f64, f64) {
    let n = params::l2_norm(theta);
    (1.0 / (1.0 + n), n)
}

/// One synthetic run whose combine step is either flat
/// (`Aggregator::combine`, `shards == 0`) or the sharded cascade
/// ([`combine_sharded`], `shards >= 1`) — everything else identical.
struct Harness {
    theta: Vec<f32>,
    sampler: ClientSampler,
    transport: Transport,
    comms: CommSim,
    agg: Box<dyn Aggregator>,
    shards: usize,
    link: TierLink,
    tier: TierState,
    accuracy: LearningCurve,
    test_loss: LearningCurve,
    client_steps: u64,
    eval_every: u64,
    /// Emulate `--workers N`: client updates computed out of dispatch
    /// order, then sorted back to slot order before encoding — the same
    /// guarantee `ParallelExec` gives the server loop.
    scrambled_workers: bool,
    meta: RunMeta,
}

fn harness(spec: &str, codec: Option<&str>, shards: usize) -> Harness {
    let transport_cfg = TransportConfig::parse(codec, codec.map(|_| "delta")).unwrap();
    let transport = Transport::new(transport_cfg, K, DIM, SEED);
    let agg = AggConfig { spec: spec.into(), ..Default::default() }.build().unwrap();
    let meta = RunMeta {
        label: format!("synthetic shards={shards}"),
        agg: agg.label(),
        codec: transport.codec_label(),
        seed: SEED,
        clients: K as u64,
        dim: DIM as u64,
        lr_decay: 1.0,
        eval_every: 2,
        // the shard count is part of the fingerprint (as in the server's
        // RunMeta): resuming under a different S would blend two
        // topologies' tier accounting
        harness: format!("shards={shards}"),
    };
    Harness {
        theta: (0..DIM).map(|i| (i as f32 * 0.01).sin()).collect(),
        sampler: ClientSampler::new(SEED),
        transport,
        comms: CommSim::new(CommModel::default(), SEED),
        agg,
        shards,
        link: TierLink::default(),
        tier: TierState::default(),
        accuracy: LearningCurve::new(),
        test_loss: LearningCurve::new(),
        client_steps: 0,
        eval_every: 2,
        scrambled_workers: false,
        meta,
    }
}

impl Harness {
    /// One synchronous round, mirroring the server loop's state flow.
    fn round(&mut self, round: u64, last: u64, w: &mut RunWriter) {
        self.transport.publish(round, &self.theta);
        let est_up = self.transport.up_plan_bytes();
        let picks = self.sampler.sample(round, K, M);
        let mut down_total = 0u64;
        for &c in &picks {
            down_total += self.transport.downlink(c, round, &self.theta);
        }
        // "worker pool": compute raw updates in whatever order the pool
        // finishes them, then restore dispatch-slot order — encode and
        // aggregate always see the same sequence
        let mut slots: Vec<(usize, usize, Vec<f32>)> = Vec::new();
        let order: Vec<usize> = if self.scrambled_workers {
            (0..picks.len()).rev().collect()
        } else {
            (0..picks.len()).collect()
        };
        for slot in order {
            let ck = picks[slot];
            self.client_steps += 5;
            slots.push((slot, ck, synth_delta(round, ck, &self.theta)));
        }
        slots.sort_by_key(|(slot, _, _)| *slot);
        let mut wire_up = 0u64;
        let mut deltas: Vec<(f32, Vec<f32>)> = Vec::new();
        for (_, ck, mut delta) in slots {
            wire_up += self.transport.encode_up(ck, &mut delta).unwrap();
            deltas.push(((ck % 3 + 1) as f32, delta));
        }
        let refs: Vec<(f32, &[f32])> = deltas.iter().map(|(w, d)| (*w, d.as_slice())).collect();
        let agg_delta = if self.shards > 0 {
            let sc = combine_sharded(self.agg.as_ref(), &refs, self.shards, &self.link).unwrap();
            self.tier.up_bytes += sc.up_bytes;
            self.tier.down_bytes += sc.down_bytes;
            self.tier.frames += sc.frames;
            self.tier.seconds += sc.seconds;
            sc.delta
        } else {
            self.agg.combine(&refs).unwrap()
        };
        let step = self.agg.step(round, agg_delta).unwrap();
        params::axpy(&mut self.theta, 1.0, &step);
        // tier-1 seconds stay OUT of the comm simulator: curve.csv's
        // sim_seconds must match the flat run byte-for-byte
        let rc = self.comms.ingest(wire_up, down_total, 1.0);
        if round % self.eval_every == 0 || round == last {
            let (acc, loss) = fake_eval(&self.theta);
            self.accuracy.push(round, acc);
            self.test_loss.push(round, loss);
            let server_state = fmt_state_norms(&self.agg.state_norms());
            w.record(&RoundRecord {
                round,
                test_accuracy: acc,
                test_loss: loss,
                train_loss: None,
                clients: picks.len(),
                lr: 0.1,
                up_bytes: rc.bytes_up,
                down_bytes: rc.bytes_down,
                codec: &self.meta.codec,
                sim_seconds: self.comms.totals().sim_seconds,
                dropped: 0,
                deadline_misses: 0,
                agg: &self.meta.agg,
                server_state: &server_state,
                staleness_mean: 0.0,
                buffer_fill: 0,
            })
            .unwrap();
        }
    }

    fn run(&mut self, rounds: u64, root: &PathBuf, name: &str) -> PathBuf {
        let mut w = RunWriter::create(root, name).unwrap();
        let dir = w.dir().to_path_buf();
        for round in 1..=rounds {
            self.round(round, rounds, &mut w);
        }
        w.finish(&[("rounds", rounds.to_string())]).unwrap();
        dir
    }

    fn snapshot(&self, round: u64) -> Snapshot {
        Snapshot {
            round,
            meta: self.meta.clone(),
            theta: self.theta.clone(),
            client_steps: self.client_steps,
            sampler: self.sampler.state(),
            agg: AggState {
                label: self.agg.label(),
                bytes: self.agg.state_save(),
            },
            transport: self.transport.state_save(),
            comms: self.comms.state_save(),
            fleet: FleetState {
                totals: FleetTotals::default(),
                dropped_since_eval: 0,
                misses_since_eval: 0,
            },
            curves: CurveState {
                accuracy: self.accuracy.points().to_vec(),
                test_loss: self.test_loss.points().to_vec(),
                train_loss: None,
            },
            dp: None,
            tier: (self.shards > 0).then_some(self.tier),
            async_state: None,
        }
    }

    /// The exact restore sequence `federated::server::run` performs.
    fn restore(&mut self, snap: Snapshot) {
        assert_eq!(snap.meta, self.meta, "config fingerprint mismatch");
        self.theta = snap.theta;
        self.sampler.restore_state(snap.sampler);
        self.agg.state_load(&snap.agg.bytes).unwrap();
        self.transport.state_load(snap.transport).unwrap();
        self.comms.state_load(snap.comms);
        self.accuracy = LearningCurve::from_points(snap.curves.accuracy).unwrap();
        self.test_loss = LearningCurve::from_points(snap.curves.test_loss).unwrap();
        self.client_steps = snap.client_steps;
        self.tier = snap.tier.unwrap_or_default();
    }
}

fn read_curve(dir: &PathBuf) -> Vec<u8> {
    std::fs::read(dir.join("curve.csv")).unwrap()
}

// ---------------------------------------------------- tentpole identity

/// The headline property: for every mean-family rule × codec × shard
/// count, S-sharded runs produce byte-identical curve.csv — and
/// bit-identical θ — versus the flat run, while the tier accounting
/// records real cascade traffic.
#[test]
fn sharded_runs_match_flat_byte_for_byte() {
    let rounds = 8u64;
    for spec in ["fedavg", "fedavgm:0.8", "fedadam:0.01"] {
        for codec in [None, Some("topk:30|q8")] {
            let tag = format!(
                "matrix-{}-{}",
                spec.split(':').next().unwrap(),
                codec.map(|_| "topk").unwrap_or("dense")
            );
            let root = test_root(&tag);
            let mut flat = harness(spec, codec, 0);
            let flat_dir = flat.run(rounds, &root, "flat");
            let flat_curve = read_curve(&flat_dir);
            assert!(!flat_curve.is_empty());
            for s in [1usize, 2, 7] {
                let mut sharded = harness(spec, codec, s);
                let dir = sharded.run(rounds, &root, &format!("s{s}"));
                assert_eq!(
                    read_curve(&dir),
                    flat_curve,
                    "{spec} codec={codec:?} S={s}: curve.csv diverged from flat"
                );
                let same_theta = flat
                    .theta
                    .iter()
                    .zip(&sharded.theta)
                    .all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(same_theta, "{spec} codec={codec:?} S={s}: θ diverged");
                assert!(sharded.tier.frames > 0, "S={s}: cascade shipped no frames");
            }
            std::fs::remove_dir_all(root).ok();
        }
    }
}

/// Worker-pool completion order must not leak into the sharded result:
/// updates finish out of order, get slot-sorted, and the curve matches
/// both the in-order sharded run and the flat run.
#[test]
fn worker_completion_order_is_invisible() {
    let rounds = 8u64;
    let root = test_root("workers");
    let mut flat = harness("fedavgm:0.8", Some("topk:30|q8"), 0);
    let flat_dir = flat.run(rounds, &root, "flat");
    let mut ordered = harness("fedavgm:0.8", Some("topk:30|q8"), 2);
    let ordered_dir = ordered.run(rounds, &root, "ordered");
    let mut scrambled = harness("fedavgm:0.8", Some("topk:30|q8"), 2);
    scrambled.scrambled_workers = true;
    let scrambled_dir = scrambled.run(rounds, &root, "scrambled");
    let flat_curve = read_curve(&flat_dir);
    assert_eq!(read_curve(&ordered_dir), flat_curve);
    assert_eq!(read_curve(&scrambled_dir), flat_curve);
    assert_eq!(ordered.tier, scrambled.tier, "tier accounting must be order-free too");
    std::fs::remove_dir_all(root).ok();
}

/// The harness's cumulative tier accounting follows the cascade's frame
/// arithmetic exactly: per round, `min(S, m)` non-empty shards ship one
/// dense up-frame each and `non_empty − 1` down-frames, serialized over
/// the default link.
#[test]
fn tier_accounting_is_deterministic() {
    let rounds = 6u64;
    let s = 3usize;
    let root = test_root("accounting");
    let mut h = harness("fedavg", None, s);
    h.run(rounds, &root, "acct");
    let fb = HEADER_BYTES + 4 * DIM as u64;
    let non_empty = s.min(M) as u64; // sampler returns exactly M picks
    assert_eq!(h.tier.up_bytes, rounds * non_empty * fb);
    assert_eq!(h.tier.down_bytes, rounds * (non_empty - 1) * fb);
    assert_eq!(h.tier.frames, rounds * (2 * non_empty - 1));
    let per_round = (2.0 * non_empty as f64 - 1.0)
        * tier_transfer_seconds(&TierLink::default(), fb);
    assert!((h.tier.seconds - rounds as f64 * per_round).abs() < 1e-9);
    std::fs::remove_dir_all(root).ok();
}

// ------------------------------------------------ robust-rule rejection

/// `trimmed`/`median` × shards must fail loudly, not fall back to flat:
/// the error names the rule and points at the design rationale.
#[test]
fn robust_rules_refuse_to_shard() {
    let link = TierLink::default();
    let deltas: Vec<(f32, Vec<f32>)> = (0..5)
        .map(|c| (1.0 + c as f32, vec![0.25f32; 33]))
        .collect();
    let refs: Vec<(f32, &[f32])> = deltas.iter().map(|(w, d)| (*w, d.as_slice())).collect();
    for spec in ["trimmed:0.2", "median"] {
        let agg = AggConfig { spec: spec.into(), ..Default::default() }.build().unwrap();
        let err = combine_sharded(agg.as_ref(), &refs, 2, &link).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains(&agg.label()), "{spec}: error must name the rule: {msg}");
        assert!(msg.contains("order statistics"), "{spec}: {msg}");
        assert!(msg.contains("DESIGN.md §11"), "{spec}: {msg}");
    }
}

// ------------------------------------------------- checkpoint + resume

/// Satellite 3, engine-free: a sharded run checkpointed mid-flight and
/// resumed is byte-identical to the uninterrupted sharded run — and the
/// snapshot's `TIER` section restores the cumulative cascade totals, so
/// the resumed accounting matches too.
#[test]
fn sharded_resume_is_bit_identical() {
    let root = test_root("resume");
    let (r1, r2) = (6u64, 12u64);
    let ckpt_round = 5u64; // off the eval cadence, like runstate.rs

    let mut full = harness("fedavgm:0.8", Some("topk:30|q8"), 2);
    let full_dir = full.run(r2, &root, "full");

    let mut part = harness("fedavgm:0.8", Some("topk:30|q8"), 2);
    let mut w = RunWriter::create(&root, "resumed").unwrap();
    let part_dir = w.dir().to_path_buf();
    let ckpts = checkpoint_dir(&part_dir);
    for round in 1..=r1 {
        part.round(round, r2, &mut w);
        if round <= ckpt_round {
            part.snapshot(round).write(&ckpts, 2).unwrap();
        }
    }
    drop(w); // kill: no finish()

    let (_, snap) = Snapshot::load_latest(&part_dir).unwrap().expect("snapshots exist");
    assert_eq!(snap.round, ckpt_round);
    assert!(snap.tier.is_some(), "sharded snapshot must carry the TIER section");
    let mut resumed = harness("fedavgm:0.8", Some("topk:30|q8"), 2);
    resumed.restore(snap);
    let mut w = RunWriter::reopen(&part_dir, ckpt_round).unwrap();
    for round in ckpt_round + 1..=r2 {
        resumed.round(round, r2, &mut w);
    }
    w.finish(&[("rounds", r2.to_string())]).unwrap();

    assert_eq!(
        read_curve(&part_dir),
        read_curve(&full_dir),
        "resumed sharded curve.csv != uninterrupted"
    );
    assert_eq!(resumed.tier, full.tier, "resumed tier totals != uninterrupted");
    std::fs::remove_dir_all(root).ok();
}

/// The shard count is part of the resume fingerprint: a checkpoint taken
/// under S=2 must not restore into an S=3 (or flat) invocation — the
/// snapshot carries cumulative tier totals that only mean anything under
/// the topology that produced them.
#[test]
fn resume_refuses_a_different_shard_count() {
    let root = test_root("refuse");
    let mut h2 = harness("fedavg", None, 2);
    let mut w = RunWriter::create(&root, "s2").unwrap();
    for round in 1..=3 {
        h2.round(round, 3, &mut w);
    }
    let snap = h2.snapshot(3);
    for other in [0usize, 1, 3] {
        let h = harness("fedavg", None, other);
        assert_ne!(
            snap.meta, h.meta,
            "S=2 checkpoint fingerprint must differ from S={other}"
        );
    }
    // same S: fingerprint matches and restore goes through
    let mut back = harness("fedavg", None, 2);
    back.restore(snap);
    assert_eq!(back.tier, h2.tier);
    std::fs::remove_dir_all(root).ok();
}

// ------------------------------------- full-stack (artifact-gated) tests

/// The identity over the real training stack: `--shards 3 --workers 4`
/// versus flat sequential, same seed — final θ bit-equal, curve.csv
/// byte-equal, and the sharded summary carries the tier fields.
#[test]
fn server_sharded_bit_identity_over_artifacts() {
    use fedavg::config::{BatchSize, FedConfig, Partition};
    use fedavg::coordinator::{FleetConfig, FleetProfile};
    use fedavg::federated::{self, ServerOptions};
    use fedavg::runtime::Engine;

    let dir = Engine::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {dir:?} — run `make artifacts`");
        return;
    }
    let eng = Engine::load(dir).expect("engine");
    let fed = fedavg::exper::mnist_fed(0.05, Partition::Iid, 77);
    let cfg = FedConfig {
        model: "mnist_2nn".into(),
        c: 0.3,
        e: 1,
        b: BatchSize::Fixed(10),
        lr: 0.1,
        rounds: 4,
        eval_every: 1,
        seed: 77,
        ..Default::default()
    };
    let opts = |telemetry: Option<RunWriter>, shards: usize, workers: usize| ServerOptions {
        eval_cap: Some(200),
        telemetry,
        transport: TransportConfig::parse(Some("topk:0.02|q8"), Some("delta")).unwrap(),
        agg: AggConfig { spec: "fedavgm:0.9".into(), ..Default::default() },
        fleet: FleetConfig {
            profile: FleetProfile::Mobile,
            overselect: 0.3,
            shards,
            workers,
            ..FleetConfig::default()
        },
        ..Default::default()
    };
    let root = test_root("server");

    let w = RunWriter::create(&root, "flat").unwrap();
    let flat_dir = w.dir().to_path_buf();
    let flat = federated::run(&eng, &fed, &cfg, opts(Some(w), 0, 1)).unwrap();

    let w = RunWriter::create(&root, "sharded").unwrap();
    let sharded_dir = w.dir().to_path_buf();
    let sharded = federated::run(&eng, &fed, &cfg, opts(Some(w), 3, 4)).unwrap();

    assert_eq!(flat.final_theta, sharded.final_theta, "sharded θ diverged from flat");
    assert_eq!(
        read_curve(&flat_dir),
        read_curve(&sharded_dir),
        "sharded curve.csv diverged from flat"
    );
    let summary = std::fs::read_to_string(sharded_dir.join("summary.json")).unwrap();
    assert!(summary.contains("\"shards\": 3"), "{summary}");
    for field in ["tier_up_bytes", "tier_down_bytes", "tier_frames", "tier_seconds"] {
        assert!(summary.contains(field), "missing {field}: {summary}");
    }
    let flat_summary = std::fs::read_to_string(flat_dir.join("summary.json")).unwrap();
    assert!(
        !flat_summary.contains("tier_up_bytes"),
        "flat run must not report tier fields: {flat_summary}"
    );
    std::fs::remove_dir_all(root).ok();
}

/// Server-level startup rejections (mirroring the PR 3 secure-agg
/// matrix): robust rules and secure aggregation both refuse `--shards`
/// before any training happens.
#[test]
fn server_rejects_shards_with_robust_rules_and_secure_agg() {
    use fedavg::config::{BatchSize, FedConfig, Partition};
    use fedavg::coordinator::FleetConfig;
    use fedavg::federated::{self, ServerOptions};
    use fedavg::runtime::Engine;

    let dir = Engine::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {dir:?} — run `make artifacts`");
        return;
    }
    let eng = Engine::load(dir).expect("engine");
    let fed = fedavg::exper::mnist_fed(0.05, Partition::Iid, 7);
    let cfg = FedConfig {
        model: "mnist_2nn".into(),
        c: 0.1,
        e: 1,
        b: BatchSize::Fixed(10),
        lr: 0.1,
        rounds: 1,
        eval_every: 1,
        seed: 7,
        ..Default::default()
    };
    let sharded = || ServerOptions {
        fleet: FleetConfig { shards: 2, ..FleetConfig::default() },
        ..Default::default()
    };
    for spec in ["median", "trimmed:0.2"] {
        let mut o = sharded();
        o.agg.spec = spec.into();
        let err = federated::run(&eng, &fed, &cfg, o).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("order statistics"), "{spec}: {msg}");
    }
    let mut o = sharded();
    o.secure_agg = true;
    let err = federated::run(&eng, &fed, &cfg, o).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("secure-agg"), "{msg}");
    assert!(msg.contains("full cohort"), "{msg}");
    // the same specs run fine flat — the refusal is about sharding
    let mut o = ServerOptions::default();
    o.agg.spec = "median".into();
    o.eval_cap = Some(50);
    assert!(federated::run(&eng, &fed, &cfg, o).is_ok());
}
