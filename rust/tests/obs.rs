//! Observability regression suite (DESIGN.md §10).
//!
//! Three guarantees under test. (1) **Span determinism**: the span
//! *multiset* — every record minus wall clock, worker id, and append
//! order — is identical under `--workers N` and the serial path.
//! (2) **Metrics continuity**: registry counters ride the existing
//! snapshot sections, so a resumed run reports the same cumulative
//! totals as one that never stopped. (3) **Byte-identity**: with
//! tracing off nothing changes, and with tracing *on* the run's
//! curve.csv is still byte-identical — observation must never perturb
//! the trajectory. Engine-free tests drive the tracer/registry/bench
//! layers directly; artifact-gated tests drive the real server.

use std::collections::BTreeMap;
use std::path::PathBuf;

use fedavg::config::{BatchSize, FedConfig, Partition};
use fedavg::federated::{self, ServerOptions};
use fedavg::obs::bench::{check_bencher, params_hot_path, validate_snapshot, write_snapshot};
use fedavg::obs::{read_trace, Metrics, Tracer};
use fedavg::runstate::{CheckpointConfig, ResumeFrom, Snapshot};
use fedavg::runtime::pool::WorkerPool;
use fedavg::runtime::Engine;
use fedavg::telemetry::RunWriter;

fn test_root(tag: &str) -> PathBuf {
    let root = PathBuf::from(format!("target/test-runs/obs-{tag}-{}", std::process::id()));
    std::fs::remove_dir_all(&root).ok();
    root
}

/// Multiset of schedule-independent span identities.
fn key_multiset(recs: &[fedavg::obs::TraceRecord]) -> BTreeMap<String, usize> {
    let mut m = BTreeMap::new();
    for r in recs {
        *m.entry(format!("{:?}", r.key())).or_insert(0) += 1;
    }
    m
}

// ------------------------------------------------------- engine-free

/// A worker pool emitting `local_train` spans must produce the same
/// span multiset whatever the worker count — only worker ids, wall
/// times, and append order may differ between schedules.
#[test]
fn pool_span_multiset_matches_serial() {
    let root = test_root("pool");
    let trace_of = |workers: usize| -> Vec<fedavg::obs::TraceRecord> {
        let path = root.join(format!("w{workers}.jsonl"));
        let tracer = Tracer::to_file(&path).unwrap();
        for round in 1..=3u64 {
            let root_sp = tracer.begin(round, "round", 0);
            let tr = tracer.clone();
            let pool: WorkerPool<(u64, u64), u64> = WorkerPool::new(
                workers,
                Ok,
                move |wid: &mut usize, (r, client): (u64, u64)| {
                    let sp = tr
                        .begin(r, "local_train", 2)
                        .map(|s| s.client(client).worker(*wid as u64).bytes(client * 64));
                    // simulated work so spans have nonzero wall time
                    std::hint::black_box((0..500u64).sum::<u64>());
                    tr.end(sp);
                    client
                },
            )
            .unwrap();
            let jobs: Vec<(u64, u64)> = (0..8).map(|c| (round, c)).collect();
            let mut outs = pool.map(jobs).unwrap();
            outs.sort_unstable();
            assert_eq!(outs, (0..8).collect::<Vec<u64>>());
            tracer.end(root_sp);
        }
        tracer.finish(&Metrics::default()).unwrap().expect("enabled");
        read_trace(&path).unwrap()
    };

    let serial = trace_of(1);
    let parallel = trace_of(4);
    assert_eq!(serial.len(), parallel.len());
    assert_eq!(
        key_multiset(&serial),
        key_multiset(&parallel),
        "span multiset depends on the schedule"
    );
    // seq is the append order: dense from 0 in both traces
    for (i, r) in parallel.iter().enumerate() {
        assert_eq!(r.seq, i as u64);
    }
    std::fs::remove_dir_all(root).ok();
}

/// The server's resume path re-seeds the registry from snapshot
/// sections with `marked = value` (nothing pending at the checkpoint
/// boundary unless the snapshot says so). Replaying the same increments
/// split across a save/seed boundary must land on the same totals and
/// the same pending remainder as an uninterrupted sequence.
#[test]
fn metrics_reseed_matches_uninterrupted() {
    // uninterrupted: 6 "rounds" of accounting, curve row every 2 rounds
    let full = Metrics::default();
    let mut full_rows: Vec<(u64, u64)> = Vec::new();
    for round in 1..=6u64 {
        full.add("fleet.dropped", round % 2);
        full.add("wire.up_bytes", 100);
        if round % 2 == 0 {
            full_rows.push((round, full.pending("fleet.dropped")));
            full.mark("fleet.dropped");
        }
    }

    // interrupted at round 3 (off the eval cadence — drops are pending)
    let part = Metrics::default();
    let mut part_rows: Vec<(u64, u64)> = Vec::new();
    for round in 1..=3u64 {
        part.add("fleet.dropped", round % 2);
        part.add("wire.up_bytes", 100);
        if round % 2 == 0 {
            part_rows.push((round, part.pending("fleet.dropped")));
            part.mark("fleet.dropped");
        }
    }
    let (saved_total, saved_pending) =
        (part.counter("fleet.dropped"), part.pending("fleet.dropped"));
    let saved_bytes = part.counter("wire.up_bytes");

    // "resume": fresh registry seeded exactly as federated::server does
    let resumed = Metrics::default();
    resumed.seed_counter("fleet.dropped", saved_total, saved_total - saved_pending);
    resumed.seed_counter("wire.up_bytes", saved_bytes, saved_bytes);
    for round in 4..=6u64 {
        resumed.add("fleet.dropped", round % 2);
        resumed.add("wire.up_bytes", 100);
        if round % 2 == 0 {
            part_rows.push((round, resumed.pending("fleet.dropped")));
            resumed.mark("fleet.dropped");
        }
    }

    assert_eq!(full_rows, part_rows, "per-row drop counts diverged across resume");
    assert_eq!(resumed.counter("fleet.dropped"), full.counter("fleet.dropped"));
    assert_eq!(resumed.counter("wire.up_bytes"), full.counter("wire.up_bytes"));

    // and the registry's own byte format round-trips the lot
    let reloaded = Metrics::default();
    reloaded.state_load(&resumed.state_save()).unwrap();
    assert_eq!(reloaded.snapshot(), resumed.snapshot());
}

/// `fedavg bench --check` end-to-end for one cheap area: run it on the
/// minimal-budget bencher, write the snapshot, re-validate from disk.
#[test]
fn bench_snapshot_records_and_validates() {
    let root = test_root("bench");
    let mut b = check_bencher();
    params_hot_path(&mut b);
    assert!(!b.results().is_empty());
    let path = root.join("BENCH_params_hot_path.json");
    write_snapshot(&path, "params_hot_path", b.results()).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let cases = validate_snapshot(&text).unwrap();
    assert_eq!(cases, b.results().len());
    std::fs::remove_dir_all(root).ok();
}

// ------------------------------------------------- artifact-gated

fn engine() -> Option<Engine> {
    let dir = Engine::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {dir:?} — run `make artifacts`");
        return None;
    }
    Some(Engine::load(dir).expect("engine"))
}

fn base_cfg() -> FedConfig {
    FedConfig {
        model: "mnist_2nn".into(),
        c: 0.4,
        e: 1,
        b: BatchSize::Fixed(10),
        lr: 0.1,
        rounds: 4,
        eval_every: 2,
        seed: 91,
        ..Default::default()
    }
}

fn base_opts(telemetry: Option<RunWriter>) -> ServerOptions {
    ServerOptions {
        eval_cap: Some(200),
        telemetry,
        ..Default::default()
    }
}

/// The §10 acceptance bar: a traced run writes the same curve.csv as an
/// untraced one (observation never perturbs the trajectory), its trace
/// is well-formed, and the depth-1 phases account for ≥ 90% of measured
/// round wall time.
#[test]
fn traced_run_is_byte_identical_and_covered() {
    let Some(eng) = engine() else { return };
    let fed = fedavg::exper::mnist_fed(0.05, Partition::Iid, 91);
    let cfg = base_cfg();
    let root = test_root("bytes");

    let w = RunWriter::create(&root, "plain").unwrap();
    let plain_dir = w.dir().to_path_buf();
    let plain = federated::run(&eng, &fed, &cfg, base_opts(Some(w))).unwrap();

    let w = RunWriter::create(&root, "traced").unwrap();
    let traced_dir = w.dir().to_path_buf();
    let mut opts = base_opts(Some(w));
    let trace_path = traced_dir.join("trace.jsonl");
    opts.trace = Tracer::to_file(&trace_path).unwrap();
    let metrics = Metrics::default();
    opts.metrics = metrics.clone();
    let traced = federated::run(&eng, &fed, &cfg, opts).unwrap();

    assert_eq!(plain.final_theta, traced.final_theta, "tracing changed the trajectory");
    let a = std::fs::read(plain_dir.join("curve.csv")).unwrap();
    let b = std::fs::read(traced_dir.join("curve.csv")).unwrap();
    assert!(!a.is_empty() && a == b, "traced curve.csv != untraced curve.csv");

    let recs = read_trace(&trace_path).unwrap();
    let rounds: Vec<&fedavg::obs::TraceRecord> =
        recs.iter().filter(|r| r.depth == 0).collect();
    assert_eq!(rounds.len(), cfg.rounds, "one depth-0 span per round");
    let root_ns: u64 = rounds.iter().map(|r| r.wall_ns).sum();
    let phase_ns: u64 = recs.iter().filter(|r| r.depth == 1).map(|r| r.wall_ns).sum();
    assert!(
        phase_ns as f64 >= 0.90 * root_ns as f64,
        "depth-1 coverage {:.1}% < 90%",
        100.0 * phase_ns as f64 / root_ns as f64
    );
    // the registry absorbed the run's accounting
    assert_eq!(metrics.counter("rounds"), cfg.rounds as u64);
    assert_eq!(metrics.counter("wire.up_bytes"), traced.comm.bytes_up);
    assert_eq!(metrics.counter("wire.down_bytes"), traced.comm.bytes_down);
    assert_eq!(metrics.counter("client.steps"), traced.client_steps);
    std::fs::remove_dir_all(root).ok();
}

/// `--workers 2 --trace` must reproduce the serial trace's span
/// multiset (and the serial trajectory) exactly.
#[test]
fn worker_trace_matches_serial_over_artifacts() {
    let Some(eng) = engine() else { return };
    let fed = fedavg::exper::mnist_fed(0.05, Partition::Iid, 92);
    let mut cfg = base_cfg();
    cfg.seed = 92;
    let root = test_root("workers");

    let run_with = |workers: usize| {
        let path = root.join(format!("w{workers}.jsonl"));
        let mut opts = base_opts(None);
        opts.fleet.workers = workers;
        opts.trace = Tracer::to_file(&path).unwrap();
        let res = federated::run(&eng, &fed, &cfg, opts).unwrap();
        (res, read_trace(&path).unwrap())
    };
    let (serial, serial_recs) = run_with(1);
    let (parallel, parallel_recs) = run_with(2);

    assert_eq!(serial.final_theta, parallel.final_theta, "--workers 2 diverged");
    assert_eq!(
        key_multiset(&serial_recs),
        key_multiset(&parallel_recs),
        "span multiset depends on worker count"
    );
    // the pool path labels local_train spans with client AND worker ids
    let lt = parallel_recs.iter().find(|r| r.phase == "local_train").unwrap();
    assert!(lt.client.is_some() && lt.worker.is_some());
    std::fs::remove_dir_all(root).ok();
}

/// Registry counters ride the snapshot: a resumed run's registry must
/// report the same cumulative totals as an uninterrupted run's.
#[test]
fn resumed_metrics_are_cumulative_over_artifacts() {
    let Some(eng) = engine() else { return };
    let fed = fedavg::exper::mnist_fed(0.05, Partition::Iid, 93);
    let cfg = |rounds| FedConfig {
        rounds,
        seed: 93,
        ..base_cfg()
    };
    let root = test_root("resume");

    let w = RunWriter::create(&root, "full").unwrap();
    let full_metrics = Metrics::default();
    let mut o = base_opts(Some(w));
    o.metrics = full_metrics.clone();
    federated::run(&eng, &fed, &cfg(6), o).unwrap();

    let w = RunWriter::create(&root, "resumed").unwrap();
    let part_dir = w.dir().to_path_buf();
    let mut o = base_opts(Some(w));
    o.checkpoint = Some(CheckpointConfig { every: 3, keep: 2 });
    federated::run(&eng, &fed, &cfg(3), o).unwrap();
    let (_, snap) = Snapshot::load_latest(&part_dir).unwrap().expect("checkpoint");
    let resumed_metrics = Metrics::default();
    let mut o = base_opts(None);
    o.metrics = resumed_metrics.clone();
    o.checkpoint = Some(CheckpointConfig { every: 3, keep: 2 });
    o.resume = Some(ResumeFrom {
        snapshot: snap,
        run_dir: part_dir,
    });
    federated::run(&eng, &fed, &cfg(6), o).unwrap();

    for name in ["rounds", "wire.up_bytes", "wire.down_bytes", "client.steps"] {
        assert_eq!(
            resumed_metrics.counter(name),
            full_metrics.counter(name),
            "{name}: resumed registry total != uninterrupted total"
        );
    }
    std::fs::remove_dir_all(root).ok();
}
