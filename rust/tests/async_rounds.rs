//! Buffered-async & semi-sync round modes — virtual-clock determinism
//! and fault-injection suite (DESIGN.md §12).
//!
//! The tentpole guarantee under test: both alternative round modes are
//! **pure functions of the seeded fleet's event times**. `--async-buffer
//! K` fires combine∘step whenever K staleness-weighted deltas have
//! arrived in virtual-clock `(finish time, slot)` order; `--late-policy
//! discount` splices past-deadline stragglers into the round their
//! upload lands in. Neither consults wall clock or worker scheduling,
//! so every trajectory is byte-identical across `--workers N`,
//! checkpointable between any two buffer applications, and — with
//! `--staleness-decay 1.0` and a buffer equal to the cohort — the async
//! run collapses to the synchronous path bit-for-bit.
//!
//! An engine-free harness (mirroring `rust/tests/shards.rs`) drives the
//! real subsystems — sampler, fleet scheduler, transport with error
//! feedback, stateful aggregators, the staleness math itself — through
//! the same state flow as `federated::server::run`'s async and
//! semi-sync branches. Seeded abort/duplicate faults ride the
//! `fault_of` stream: an aborted client's delta never uploads (its
//! error-feedback residual is untouched), and a duplicate delivery is
//! refused idempotently. Artifact-gated tests repeat the sync↔async
//! identity and the startup refusal matrix over the full training
//! stack.

use std::path::PathBuf;

use fedavg::comms::{CommModel, CommSim, Transport, TransportConfig};
use fedavg::coordinator::{
    fault_of, plan_async_wave, plan_round, Fault, FaultConfig, Fleet, FleetConfig,
    FleetProfile, FleetTotals, LatePolicy, RoundPlan, WavePlan,
};
use fedavg::data::rng::hash3_unit;
use fedavg::federated::aggregate::{
    fmt_state_norms, staleness_scale, staleness_weight, AggConfig, Aggregator,
};
use fedavg::federated::ClientSampler;
use fedavg::metrics::LearningCurve;
use fedavg::params;
use fedavg::runstate::{
    checkpoint_dir, AggState, AsyncState, BufferedDelta, CurveState, FleetState, RunMeta,
    Snapshot,
};
use fedavg::telemetry::{RoundRecord, RunWriter};

const DIM: usize = 301;
const K: usize = 12;
const M: usize = 4;
const SEED: u64 = 23;
/// Uniform per-client local step count (the scheduler's `steps_of`).
const STEPS: f64 = 5.0;

fn test_root(tag: &str) -> PathBuf {
    let root = PathBuf::from(format!(
        "target/test-runs/async-{tag}-{}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&root).ok();
    root
}

/// Deterministic stand-in for a client's local update (same recipe as
/// `rust/tests/shards.rs`): a function of (round, client, θ) so a single
/// wrong bit in any combine propagates into every later round.
fn synth_delta(round: u64, client: usize, theta: &[f32]) -> Vec<f32> {
    (0..DIM)
        .map(|i| {
            (hash3_unit(round, client as u64, i as u64) as f32 - 0.5) * 0.1
                - 0.01 * theta[i]
        })
        .collect()
}

/// Fake evaluation: a smooth function of ‖θ‖ (no model involved).
fn fake_eval(theta: &[f32]) -> (f64, f64) {
    let n = params::l2_norm(theta);
    (1.0 / (1.0 + n), n)
}

// ---------------------------------------------------- fleet configs

fn sync_cfg(profile: FleetProfile, overselect: f64, deadline_s: Option<f64>) -> FleetConfig {
    FleetConfig { profile, overselect, deadline_s, ..FleetConfig::default() }
}

fn async_cfg(profile: FleetProfile, buffer: usize, decay: f64) -> FleetConfig {
    FleetConfig {
        profile,
        async_buffer: Some(buffer),
        staleness_decay: decay,
        ..FleetConfig::default()
    }
}

fn semi_cfg(profile: FleetProfile, overselect: f64, deadline: f64, decay: f64) -> FleetConfig {
    FleetConfig {
        profile,
        overselect,
        deadline_s: Some(deadline),
        late_policy: LatePolicy::Discount,
        staleness_decay: decay,
        ..FleetConfig::default()
    }
}

/// One synthetic run whose round loop is the synchronous barrier, the
/// semi-sync late queue, or the buffered-async wave — the same state
/// flow as `federated::server::run`'s three selection/apply branches,
/// with `synth_delta` standing in for ClientUpdate.
struct Harness {
    theta: Vec<f32>,
    sampler: ClientSampler,
    transport: Transport,
    comms: CommSim,
    agg: Box<dyn Aggregator>,
    fleet: Fleet,
    cfg: FleetConfig,
    /// `Some` exactly when an async round mode is active (as in the
    /// server), so the sync harness snapshots without an ASYNC section.
    astate: Option<AsyncState>,
    /// Seeded abort/duplicate stream; `None` = fault-free.
    faults: Option<FaultConfig>,
    accuracy: LearningCurve,
    test_loss: LearningCurve,
    client_steps: u64,
    dropped_since_eval: usize,
    misses_since_eval: usize,
    /// Run-total Σ staleness over applied deltas — proves a test
    /// actually exercised stale applies instead of passing vacuously.
    total_staleness: u64,
    aborted: u64,
    duplicates_refused: u64,
    eval_every: u64,
    /// Emulate `--workers N`: client updates computed out of dispatch
    /// order, then sorted back to slot order before encoding — the same
    /// guarantee `ParallelExec` gives the server loop. Arrival order
    /// comes from the virtual clock either way (DESIGN.md §12).
    scrambled_workers: bool,
    meta: RunMeta,
}

fn harness(spec: &str, codec: Option<&str>, cfg: FleetConfig) -> Harness {
    let transport_cfg = TransportConfig::parse(codec, codec.map(|_| "delta")).unwrap();
    let transport = Transport::new(transport_cfg, K, DIM, SEED);
    let agg = AggConfig { spec: spec.into(), ..Default::default() }.build().unwrap();
    let astate = (cfg.async_buffer.is_some() || cfg.late_policy == LatePolicy::Discount)
        .then(AsyncState::default);
    let meta = RunMeta {
        label: "synthetic async".into(),
        agg: agg.label(),
        codec: transport.codec_label(),
        seed: SEED,
        clients: K as u64,
        dim: DIM as u64,
        lr_decay: 1.0,
        eval_every: 2,
        // the round-mode knobs are part of the fingerprint (as in the
        // server's RunMeta): a checkpoint's pending buffer only means
        // anything under the knobs that filled it
        harness: format!(
            "async=({:?},{:?},{:?}) barrier=({:?},{:?})",
            cfg.async_buffer, cfg.staleness_decay, cfg.late_policy,
            cfg.overselect, cfg.deadline_s,
        ),
    };
    Harness {
        theta: (0..DIM).map(|i| (i as f32 * 0.01).sin()).collect(),
        sampler: ClientSampler::new(SEED),
        transport,
        comms: CommSim::new(CommModel::default(), SEED),
        agg,
        fleet: Fleet::build(&cfg, K, SEED),
        cfg,
        astate,
        faults: None,
        accuracy: LearningCurve::new(),
        test_loss: LearningCurve::new(),
        client_steps: 0,
        dropped_since_eval: 0,
        misses_since_eval: 0,
        total_staleness: 0,
        aborted: 0,
        duplicates_refused: 0,
        eval_every: 2,
        scrambled_workers: false,
        meta,
    }
}

enum Sel {
    Wave(WavePlan),
    Plan(RoundPlan),
}

impl Harness {
    /// One round, mirroring the server loop's async/semi-sync state flow.
    fn round(&mut self, round: u64, last: u64, w: &mut RunWriter) {
        self.transport.publish(round, &self.theta);
        let est_up = self.transport.up_plan_bytes();
        let decay = self.cfg.staleness_decay;
        let mut down_total = 0u64;
        // disjoint field borrows for the link-pricing closure: the
        // scheduler holds fleet + sampler while the closure meters the
        // transport (exactly the server's split)
        let sel = {
            let Harness { ref fleet, ref mut sampler, ref mut transport, ref theta, .. } =
                *self;
            let mut link = |c: usize| {
                let down = transport.downlink(c, round, theta);
                down_total += down;
                (down, est_up)
            };
            if self.cfg.async_buffer.is_some() {
                let (_, wv) =
                    plan_async_wave(fleet, sampler, round, M, &mut link, |_| STEPS);
                Sel::Wave(wv)
            } else {
                let (_, p) = plan_round(
                    fleet,
                    sampler,
                    round,
                    M,
                    self.cfg.overselect,
                    self.cfg.deadline_s,
                    &mut link,
                    |_| STEPS,
                );
                Sel::Plan(p)
            }
        };
        let clock0 = self.comms.totals().sim_seconds;
        let semi = self.cfg.late_policy == LatePolicy::Discount;
        let (picks, late_now, plan, wave) = match sel {
            Sel::Wave(wv) => (wv.dispatched.clone(), Vec::new(), None, Some(wv)),
            Sel::Plan(p) => {
                let late = if semi { p.late.clone() } else { Vec::new() };
                (p.completed.clone(), late, Some(p), None)
            }
        };
        // late stragglers keep training on this round's θ — only their
        // upload lands later
        let train_list: Vec<usize> = picks
            .iter()
            .copied()
            .chain(late_now.iter().map(|&(c, _)| c))
            .collect();

        // "worker pool": compute raw updates in whatever order the pool
        // finishes them, then restore dispatch-slot order
        let mut slots: Vec<(usize, usize, Vec<f32>)> = Vec::new();
        let order: Vec<usize> = if self.scrambled_workers {
            (0..train_list.len()).rev().collect()
        } else {
            (0..train_list.len()).collect()
        };
        for slot in order {
            let ck = train_list[slot];
            self.client_steps += STEPS as u64;
            slots.push((slot, ck, synth_delta(round, ck, &self.theta)));
        }
        slots.sort_by_key(|(slot, _, _)| *slot);

        // encode in slot order; an aborted client's delta never uploads
        // (error feedback untouched); a semi-sync straggler's raw delta
        // is queued and encoded only at its apply round
        let mut wire_up = 0u64;
        let mut arrived: Vec<Option<(f32, Vec<f32>)>> = (0..picks.len()).map(|_| None).collect();
        for (slot, ck, mut delta) in slots {
            if slot < picks.len() {
                if wave.is_some() {
                    if let Some(f) = &self.faults {
                        if fault_of(f, round, ck as u64) == Fault::Abort {
                            self.aborted += 1;
                            continue;
                        }
                    }
                }
                wire_up += self.transport.encode_up(ck, &mut delta).unwrap();
                arrived[slot] = Some(((ck % 3 + 1) as f32, delta));
            } else {
                let (_, finish_t) = late_now[slot - picks.len()];
                self.astate.as_mut().unwrap().late.push(BufferedDelta {
                    dispatch_round: round,
                    slot: slot as u64,
                    client: ck as u64,
                    basis: 0,
                    weight: (ck % 3 + 1) as f32,
                    due_s: clock0 + finish_t,
                    delta,
                });
            }
        }

        let (rc, n_clients) = if let Some(wv) = wave {
            // ---- buffered-async: arrivals feed the FIFO in virtual-clock
            // order; every K deltas, one combine∘step fires
            let buf = self.cfg.async_buffer.unwrap();
            let a = self.astate.as_mut().unwrap();
            for arr in &wv.arrivals {
                let Some((weight, delta)) = arrived[arr.slot].take() else { continue };
                a.pending.push(BufferedDelta {
                    dispatch_round: round,
                    slot: arr.slot as u64,
                    client: arr.client as u64,
                    basis: a.applies_done,
                    weight,
                    due_s: 0.0,
                    delta,
                });
                if let Some(f) = &self.faults {
                    if fault_of(f, round, arr.client as u64) == Fault::Duplicate {
                        // second delivery of the same (round, client):
                        // refused — the buffer already holds one copy
                        self.duplicates_refused += 1;
                    }
                }
            }
            while a.pending.len() >= buf {
                let mut batch: Vec<BufferedDelta> = a.pending.drain(..buf).collect();
                batch.sort_by_key(|e| (e.dispatch_round, e.slot));
                let stale: Vec<(f32, u64)> = batch
                    .iter()
                    .map(|e| (e.weight, a.applies_done - e.basis))
                    .collect();
                let scale = staleness_scale(&stale, decay);
                let mut agg_delta = if scale > 0.0 {
                    let refs: Vec<(f32, &[f32])> = batch
                        .iter()
                        .zip(&stale)
                        .map(|(e, &(wt, s))| {
                            (staleness_weight(wt, decay, s), e.delta.as_slice())
                        })
                        .collect();
                    self.agg.combine(&refs).unwrap()
                } else {
                    vec![0.0f32; self.theta.len()]
                };
                if scale != 1.0 {
                    for v in agg_delta.iter_mut() {
                        *v = (*v as f64 * scale) as f32;
                    }
                }
                let step = self.agg.step(a.applies_done + 1, agg_delta).unwrap();
                params::axpy(&mut self.theta, 1.0, &step);
                a.applies_done += 1;
                a.deltas_since_eval += buf as u64;
                for &(_, s) in &stale {
                    a.stale_sum_since_eval += s;
                    self.total_staleness += s;
                }
            }
            (self.comms.ingest(wire_up, down_total, wv.round_seconds), picks.len())
        } else {
            // ---- barrier (sync / semi-sync): due late deltas join this
            // round's cohort FIRST, staleness-discounted
            let p = plan.unwrap();
            let mut due_deltas: Vec<(f32, Vec<f32>)> = Vec::new();
            let mut stale: Vec<(f32, u64)> = Vec::new();
            let cur: Vec<(f32, Vec<f32>)> = arrived.into_iter().flatten().collect();
            if let Some(a) = self.astate.as_mut() {
                let cut = clock0 + p.round_seconds;
                let mut keep = Vec::new();
                for e in a.late.drain(..) {
                    if e.due_s > cut {
                        keep.push(e);
                        continue;
                    }
                    let mut d = e.delta;
                    wire_up += self.transport.encode_up(e.client as usize, &mut d).unwrap();
                    let s = round - e.dispatch_round;
                    due_deltas.push((staleness_weight(e.weight, decay, s), d));
                    stale.push((e.weight, s));
                    a.late_applied += 1;
                }
                a.late = keep;
                for &(wt, _) in &cur {
                    stale.push((wt, 0));
                }
                a.deltas_since_eval += (due_deltas.len() + cur.len()) as u64;
                for &(_, s) in &stale {
                    a.stale_sum_since_eval += s;
                    self.total_staleness += s;
                }
            }
            let n_apply = due_deltas.len() + picks.len();
            let scale = match &self.astate {
                Some(_) => staleness_scale(&stale, decay),
                None => 1.0,
            };
            let refs: Vec<(f32, &[f32])> = due_deltas
                .iter()
                .map(|(wt, d)| (*wt, d.as_slice()))
                .chain(cur.iter().map(|(wt, d)| (*wt, d.as_slice())))
                .collect();
            let mut agg_delta = self.agg.combine(&refs).unwrap();
            if scale != 1.0 {
                for v in agg_delta.iter_mut() {
                    *v = (*v as f64 * scale) as f32;
                }
            }
            let step = self.agg.step(round, agg_delta).unwrap();
            params::axpy(&mut self.theta, 1.0, &step);
            self.dropped_since_eval += p.dropped.len() - late_now.len();
            self.misses_since_eval += p.deadline_miss as usize;
            (self.comms.ingest(wire_up, down_total, p.round_seconds), n_apply)
        };

        if round % self.eval_every == 0 || round == last {
            let (acc, loss) = fake_eval(&self.theta);
            self.accuracy.push(round, acc);
            self.test_loss.push(round, loss);
            let server_state = fmt_state_norms(&self.agg.state_norms());
            let (staleness_mean, buffer_fill) = match &self.astate {
                Some(a) => (
                    if a.deltas_since_eval > 0 {
                        a.stale_sum_since_eval as f64 / a.deltas_since_eval as f64
                    } else {
                        0.0
                    },
                    if self.cfg.async_buffer.is_some() {
                        a.pending.len()
                    } else {
                        a.late.len()
                    },
                ),
                None => (0.0, 0),
            };
            w.record(&RoundRecord {
                round,
                test_accuracy: acc,
                test_loss: loss,
                train_loss: None,
                clients: n_clients,
                lr: 0.1,
                up_bytes: rc.bytes_up,
                down_bytes: rc.bytes_down,
                codec: &self.meta.codec,
                sim_seconds: self.comms.totals().sim_seconds,
                dropped: self.dropped_since_eval,
                deadline_misses: self.misses_since_eval,
                agg: &self.meta.agg,
                server_state: &server_state,
                staleness_mean,
                buffer_fill,
            })
            .unwrap();
            self.dropped_since_eval = 0;
            self.misses_since_eval = 0;
            if let Some(a) = self.astate.as_mut() {
                a.stale_sum_since_eval = 0;
                a.deltas_since_eval = 0;
            }
        }
    }

    fn run(&mut self, rounds: u64, root: &PathBuf, name: &str) -> PathBuf {
        let mut w = RunWriter::create(root, name).unwrap();
        let dir = w.dir().to_path_buf();
        for round in 1..=rounds {
            self.round(round, rounds, &mut w);
        }
        w.finish(&[("rounds", rounds.to_string())]).unwrap();
        dir
    }

    fn snapshot(&self, round: u64) -> Snapshot {
        Snapshot {
            round,
            meta: self.meta.clone(),
            theta: self.theta.clone(),
            client_steps: self.client_steps,
            sampler: self.sampler.state(),
            agg: AggState {
                label: self.agg.label(),
                bytes: self.agg.state_save(),
            },
            transport: self.transport.state_save(),
            comms: self.comms.state_save(),
            fleet: FleetState {
                totals: FleetTotals::default(),
                dropped_since_eval: self.dropped_since_eval as u64,
                misses_since_eval: self.misses_since_eval as u64,
            },
            curves: CurveState {
                accuracy: self.accuracy.points().to_vec(),
                test_loss: self.test_loss.points().to_vec(),
                train_loss: None,
            },
            dp: None,
            tier: None,
            async_state: self.astate.clone(),
        }
    }

    /// The exact restore sequence `federated::server::run` performs.
    fn restore(&mut self, snap: Snapshot) {
        assert_eq!(snap.meta, self.meta, "config fingerprint mismatch");
        self.theta = snap.theta;
        self.sampler.restore_state(snap.sampler);
        self.agg.state_load(&snap.agg.bytes).unwrap();
        self.transport.state_load(snap.transport).unwrap();
        self.comms.state_load(snap.comms);
        self.accuracy = LearningCurve::from_points(snap.curves.accuracy).unwrap();
        self.test_loss = LearningCurve::from_points(snap.curves.test_loss).unwrap();
        self.client_steps = snap.client_steps;
        self.dropped_since_eval = snap.fleet.dropped_since_eval as usize;
        self.misses_since_eval = snap.fleet.misses_since_eval as usize;
        self.astate = snap.async_state;
    }

    fn theta_bits(&self) -> Vec<u32> {
        self.theta.iter().map(|v| v.to_bits()).collect()
    }
}

fn read_curve(dir: &PathBuf) -> Vec<u8> {
    std::fs::read(dir.join("curve.csv")).unwrap()
}

// ---------------------------------------------------- tentpole identity

/// The headline property (acceptance criterion): with `--staleness-decay
/// 1.0` and a buffer equal to the cohort size, the buffered-async run
/// reproduces the synchronous run **byte-for-byte** — same curve.csv,
/// bit-identical θ — for every mean-family rule × codec × worker
/// completion order. On the uniform fleet every wave dispatches exactly
/// M clients, so each wave fills the buffer exactly once and
/// `step(applies_done + 1)` sees the same step index as the sync path.
#[test]
fn async_equal_buffer_reduces_to_sync_byte_for_byte() {
    let rounds = 8u64;
    for spec in ["fedavg", "fedavgm:0.8", "fedadam:0.01"] {
        for codec in [None, Some("topk:30|q8")] {
            let tag = format!(
                "identity-{}-{}",
                spec.split(':').next().unwrap(),
                codec.map(|_| "topk").unwrap_or("dense")
            );
            let root = test_root(&tag);
            let mut sync = harness(spec, codec, sync_cfg(FleetProfile::Uniform, 0.0, None));
            let sync_dir = sync.run(rounds, &root, "sync");
            let sync_curve = read_curve(&sync_dir);
            assert!(!sync_curve.is_empty());
            // the new columns are in every curve header, sync included
            assert!(
                sync_curve.starts_with(b"round,")
                    && String::from_utf8_lossy(&sync_curve)
                        .lines()
                        .next()
                        .unwrap()
                        .ends_with("staleness_mean,buffer_fill"),
                "curve header must carry the async columns"
            );
            for scrambled in [false, true] {
                let mut a = harness(spec, codec, async_cfg(FleetProfile::Uniform, M, 1.0));
                a.scrambled_workers = scrambled;
                let dir = a.run(rounds, &root, &format!("async-w{}", scrambled as u8 * 3 + 1));
                assert_eq!(
                    read_curve(&dir),
                    sync_curve,
                    "{spec} codec={codec:?} scrambled={scrambled}: async curve.csv \
                     diverged from sync"
                );
                assert_eq!(
                    a.theta_bits(),
                    sync.theta_bits(),
                    "{spec} codec={codec:?} scrambled={scrambled}: θ diverged"
                );
                let a = a.astate.as_ref().unwrap();
                assert_eq!(a.applies_done, rounds, "one apply per wave");
                assert!(a.pending.is_empty(), "buffer must drain every wave");
            }
            std::fs::remove_dir_all(root).ok();
        }
    }
}

/// Worker completion order must be invisible in a *genuinely* async run
/// too (buffer smaller than the cohort, decay < 1, carryover between
/// waves): arrival order is the virtual-clock sort, never the pool's
/// finish order. On the uniform fleet (4 arrivals/wave, buffer 3) the
/// buffer carries 1–2 deltas across every wave, so stale applies are
/// guaranteed, not incidental.
#[test]
fn async_worker_completion_order_is_invisible() {
    let rounds = 10u64;
    for profile in [FleetProfile::Uniform, FleetProfile::Mobile] {
        let root = test_root(&format!("workers-{}", profile.label()));
        let mut ordered = harness("fedavgm:0.8", Some("topk:30|q8"), async_cfg(profile, 3, 0.7));
        let ordered_dir = ordered.run(rounds, &root, "ordered");
        let mut scrambled =
            harness("fedavgm:0.8", Some("topk:30|q8"), async_cfg(profile, 3, 0.7));
        scrambled.scrambled_workers = true;
        let scrambled_dir = scrambled.run(rounds, &root, "scrambled");
        assert_eq!(
            read_curve(&ordered_dir),
            read_curve(&scrambled_dir),
            "{profile:?}: worker order leaked into the async curve"
        );
        assert_eq!(ordered.theta_bits(), scrambled.theta_bits(), "{profile:?}: θ diverged");
        if profile == FleetProfile::Uniform {
            assert!(
                ordered.total_staleness > 0,
                "uniform fleet with buffer 3 must carry stale deltas across waves"
            );
        }
        std::fs::remove_dir_all(root).ok();
    }
}

// ------------------------------------------------- checkpoint + resume

/// A buffered-async run checkpointed *between two buffer applications*
/// — pending deltas in flight — and resumed is byte-identical to the
/// uninterrupted run. On the uniform fleet (4 arrivals/wave, buffer 3)
/// the checkpoint after round 5 provably holds 20 mod 3 = 2 pending
/// deltas, so the ASYNC section is doing real work.
#[test]
fn async_checkpoint_resume_is_bit_identical() {
    let root = test_root("resume");
    let (r1, r2) = (6u64, 12u64);
    let ckpt_round = 5u64; // off the eval cadence, like runstate.rs
    let cfg = || async_cfg(FleetProfile::Uniform, 3, 0.8);

    let mut full = harness("fedavgm:0.8", Some("topk:30|q8"), cfg());
    let full_dir = full.run(r2, &root, "full");

    let mut part = harness("fedavgm:0.8", Some("topk:30|q8"), cfg());
    let mut w = RunWriter::create(&root, "resumed").unwrap();
    let part_dir = w.dir().to_path_buf();
    let ckpts = checkpoint_dir(&part_dir);
    for round in 1..=r1 {
        part.round(round, r2, &mut w);
        if round <= ckpt_round {
            part.snapshot(round).write(&ckpts, 2).unwrap();
        }
    }
    drop(w); // kill: no finish()

    let (_, snap) = Snapshot::load_latest(&part_dir).unwrap().expect("snapshots exist");
    assert_eq!(snap.round, ckpt_round);
    let a = snap.async_state.as_ref().expect("async snapshot must carry the ASYNC section");
    assert_eq!(a.pending.len(), 2, "checkpoint must land mid-buffer (20 mod 3)");
    assert_eq!(a.applies_done, 6, "⌊20 / 3⌋ applies after round 5");
    assert!(
        a.stale_sum_since_eval > 0 || a.deltas_since_eval > 0,
        "ckpt off the eval cadence must carry mid-flight curve accumulators"
    );
    let mut resumed = harness("fedavgm:0.8", Some("topk:30|q8"), cfg());
    resumed.restore(snap);
    let mut w = RunWriter::reopen(&part_dir, ckpt_round).unwrap();
    for round in ckpt_round + 1..=r2 {
        resumed.round(round, r2, &mut w);
    }
    w.finish(&[("rounds", r2.to_string())]).unwrap();

    assert_eq!(
        read_curve(&part_dir),
        read_curve(&full_dir),
        "resumed async curve.csv != uninterrupted"
    );
    assert_eq!(resumed.theta_bits(), full.theta_bits(), "resumed θ != uninterrupted");
    assert_eq!(
        resumed.astate, full.astate,
        "resumed async state (applies, pending buffer) != uninterrupted"
    );
    std::fs::remove_dir_all(root).ok();
}

/// The round-mode knobs are part of the resume fingerprint: a pending
/// buffer only means anything under the buffer size / decay / policy
/// that filled it.
#[test]
fn resume_refuses_different_async_knobs() {
    let mut h = harness("fedavg", None, async_cfg(FleetProfile::Uniform, 3, 0.8));
    let root = test_root("refuse");
    let mut w = RunWriter::create(&root, "a3").unwrap();
    for round in 1..=3 {
        h.round(round, 3, &mut w);
    }
    let snap = h.snapshot(3);
    for other in [
        async_cfg(FleetProfile::Uniform, 4, 0.8),
        async_cfg(FleetProfile::Uniform, 3, 0.5),
        sync_cfg(FleetProfile::Uniform, 0.0, None),
        semi_cfg(FleetProfile::Uniform, 0.0, 10.0, 0.8),
    ] {
        let o = harness("fedavg", None, other);
        assert_ne!(snap.meta, o.meta, "fingerprint must differ: {}", o.meta.harness);
    }
    let mut back = harness("fedavg", None, async_cfg(FleetProfile::Uniform, 3, 0.8));
    back.restore(snap);
    assert_eq!(back.astate, h.astate);
    std::fs::remove_dir_all(root).ok();
}

// ------------------------------------------------------------ semi-sync

/// With a deadline nobody misses, `--late-policy discount` is inert: the
/// late queue stays empty, every staleness weight is the plain weight,
/// the normalizing scale is exactly 1.0 — byte-identical to the drop
/// policy (which is itself the plain synchronous path here).
#[test]
fn semi_sync_with_zero_late_clients_matches_sync() {
    let rounds = 8u64;
    let root = test_root("semi-zero");
    let mut sync = harness(
        "fedavgm:0.8",
        Some("topk:30|q8"),
        sync_cfg(FleetProfile::Mobile, 0.3, Some(1.0e6)),
    );
    let sync_dir = sync.run(rounds, &root, "sync");
    let mut semi = harness(
        "fedavgm:0.8",
        Some("topk:30|q8"),
        semi_cfg(FleetProfile::Mobile, 0.3, 1.0e6, 0.9),
    );
    let semi_dir = semi.run(rounds, &root, "semi");
    assert_eq!(
        read_curve(&semi_dir),
        read_curve(&sync_dir),
        "zero-late semi-sync curve.csv diverged from sync"
    );
    assert_eq!(semi.theta_bits(), sync.theta_bits(), "zero-late semi-sync θ diverged");
    let a = semi.astate.as_ref().unwrap();
    assert_eq!(a.late_applied, 0);
    assert!(a.late.is_empty());
    std::fs::remove_dir_all(root).ok();
}

/// With a tight deadline on the heterogeneous fleet, stragglers really
/// are discounted into later rounds: late deltas apply with staleness
/// measured in rounds, the trajectory genuinely departs from the drop
/// policy, and the error-feedback residuals reused at the apply round
/// keep θ finite.
#[test]
fn semi_sync_discounts_late_stragglers() {
    let rounds = 12u64;
    let root = test_root("semi-late");
    let mut drop_h = harness(
        "fedavg",
        Some("topk:30|q8"),
        sync_cfg(FleetProfile::Mobile, 0.0, Some(0.3)),
    );
    drop_h.run(rounds, &root, "drop");
    let mut semi = harness(
        "fedavg",
        Some("topk:30|q8"),
        semi_cfg(FleetProfile::Mobile, 0.0, 0.3, 0.9),
    );
    semi.run(rounds, &root, "semi");
    let a = semi.astate.as_ref().unwrap();
    assert!(a.late_applied > 0, "tight deadline on mobile fleet must produce late applies");
    assert!(
        semi.total_staleness > 0,
        "late applies must carry round-staleness > 0"
    );
    assert!(semi.theta.iter().all(|v| v.is_finite()));
    assert_ne!(
        semi.theta_bits(),
        drop_h.theta_bits(),
        "discounted stragglers must actually change the trajectory"
    );
    std::fs::remove_dir_all(root).ok();
}

// ------------------------------------------------------ fault injection

/// A seeded abort means the client's delta never uploads: no encode, so
/// its error-feedback residual is bit-untouched, the buffer does not
/// advance, and θ is unchanged — while the abort is counted.
#[test]
fn aborted_clients_preserve_error_feedback() {
    let root = test_root("abort");
    // buffer 2 on the uniform fleet: 4 arrivals/wave drain exactly twice,
    // so the pending buffer is provably empty between rounds
    let mut h = harness("fedavg", Some("topk:30|q8"), async_cfg(FleetProfile::Uniform, 2, 0.9));
    let mut w = RunWriter::create(&root, "abort").unwrap();
    for round in 1..=2 {
        h.round(round, 99, &mut w);
    }
    let residuals: Vec<u64> = (0..K).map(|c| h.transport.residual_norm(c).to_bits()).collect();
    assert!(
        h.transport.residual_l2_total() > 0.0,
        "top-k uplink must have built residual mass before the faulty round"
    );
    let theta_before = h.theta_bits();
    let applies_before = h.astate.as_ref().unwrap().applies_done;

    h.faults = Some(FaultConfig { abort_p: 1.0, duplicate_p: 0.0, seed: SEED });
    h.round(3, 99, &mut w);

    assert_eq!(h.aborted, M as u64, "every dispatched client must abort");
    assert_eq!(
        (0..K).map(|c| h.transport.residual_norm(c).to_bits()).collect::<Vec<_>>(),
        residuals,
        "aborted clients' EF residuals must be bit-untouched"
    );
    assert_eq!(h.theta_bits(), theta_before, "no delta arrived, θ must not move");
    let a = h.astate.as_ref().unwrap();
    assert_eq!(a.applies_done, applies_before);
    assert!(a.pending.is_empty());
    std::fs::remove_dir_all(root).ok();
}

/// A duplicate delivery is refused idempotently: the buffer holds
/// exactly one copy per (round, client), so a run where *every* delta is
/// delivered twice is byte-identical to the fault-free run — the only
/// trace is the refused counter.
#[test]
fn duplicate_deliveries_are_refused_idempotently() {
    let rounds = 8u64;
    let root = test_root("dup");
    let cfg = || async_cfg(FleetProfile::Uniform, 3, 0.9);
    let mut clean = harness("fedavgm:0.8", Some("topk:30|q8"), cfg());
    let clean_dir = clean.run(rounds, &root, "clean");
    let mut dup = harness("fedavgm:0.8", Some("topk:30|q8"), cfg());
    dup.faults = Some(FaultConfig { abort_p: 0.0, duplicate_p: 1.0, seed: SEED });
    let dup_dir = dup.run(rounds, &root, "dup");
    assert_eq!(
        read_curve(&dup_dir),
        read_curve(&clean_dir),
        "refused duplicates must leave the trajectory byte-identical"
    );
    assert_eq!(dup.theta_bits(), clean.theta_bits());
    assert_eq!(
        dup.duplicates_refused,
        rounds * M as u64,
        "every arrival was delivered twice; each second copy refused"
    );
    assert_eq!(dup.aborted, 0);
    std::fs::remove_dir_all(root).ok();
}

/// The fault stream itself is a pure function of (seed, round, client):
/// independent of query order, stable across replays, and disjoint
/// outcomes partition the unit interval.
#[test]
fn fault_stream_is_deterministic_and_seeded() {
    let f = FaultConfig { abort_p: 0.3, duplicate_p: 0.3, seed: 7 };
    f.validate().unwrap();
    let draw: Vec<Fault> = (0..50).map(|c| fault_of(&f, 4, c)).collect();
    let mut replay: Vec<Fault> = (0..50).rev().map(|c| fault_of(&f, 4, c)).collect();
    replay.reverse();
    assert_eq!(draw, replay, "fault coin must not depend on query order");
    let other: Vec<Fault> = (0..50).map(|c| fault_of(&FaultConfig { seed: 8, ..f }, 4, c)).collect();
    assert_ne!(draw, other, "seed must steer the stream");
    assert!(
        FaultConfig { abort_p: 0.7, duplicate_p: 0.7, seed: 0 }.validate().is_err(),
        "abort_p + duplicate_p > 1 must be refused"
    );
}

// ------------------------------------- full-stack (artifact-gated) tests

/// The acceptance identity over the real training stack: `--async-buffer
/// m --staleness-decay 1.0 --workers 4` versus the plain synchronous
/// fleet run — final θ bit-equal, curve.csv byte-equal.
#[test]
fn server_async_bit_identity_over_artifacts() {
    use fedavg::config::{BatchSize, FedConfig, Partition};
    use fedavg::federated::{self, ServerOptions};
    use fedavg::runtime::Engine;

    let dir = Engine::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {dir:?} — run `make artifacts`");
        return;
    }
    let eng = Engine::load(dir).expect("engine");
    let fed = fedavg::exper::mnist_fed(0.05, Partition::Iid, 77);
    let cfg = FedConfig {
        model: "mnist_2nn".into(),
        c: 0.3,
        e: 1,
        b: BatchSize::Fixed(10),
        lr: 0.1,
        rounds: 4,
        eval_every: 1,
        seed: 77,
        ..Default::default()
    };
    let m = (0.3f64 * fed.clients.len() as f64).ceil() as usize;
    let opts = |telemetry: Option<RunWriter>, fleet: FleetConfig| ServerOptions {
        eval_cap: Some(200),
        telemetry,
        transport: TransportConfig::parse(Some("topk:0.02|q8"), Some("delta")).unwrap(),
        agg: AggConfig { spec: "fedavgm:0.9".into(), ..Default::default() },
        fleet,
        ..Default::default()
    };
    let root = test_root("server");

    let w = RunWriter::create(&root, "sync").unwrap();
    let sync_dir = w.dir().to_path_buf();
    let sync = federated::run(
        &eng,
        &fed,
        &cfg,
        opts(Some(w), sync_cfg(FleetProfile::Uniform, 0.0, None)),
    )
    .unwrap();

    let w = RunWriter::create(&root, "async").unwrap();
    let async_dir = w.dir().to_path_buf();
    let mut fleet = async_cfg(FleetProfile::Uniform, m, 1.0);
    fleet.workers = 4;
    let asynced = federated::run(&eng, &fed, &cfg, opts(Some(w), fleet)).unwrap();

    assert_eq!(sync.final_theta, asynced.final_theta, "async θ diverged from sync");
    assert_eq!(
        read_curve(&sync_dir),
        read_curve(&async_dir),
        "async curve.csv diverged from sync"
    );
    std::fs::remove_dir_all(root).ok();
}

/// Server-level startup refusal matrix (PR 7 convention: name the flag,
/// say why, point at DESIGN.md §12) — and the one composition that IS
/// allowed: central DP over either async mode.
#[test]
fn server_rejects_async_mode_conflicts() {
    use fedavg::config::{BatchSize, FedConfig, Partition};
    use fedavg::federated::server::DpConfig;
    use fedavg::federated::{self, ServerOptions};
    use fedavg::runtime::Engine;

    let dir = Engine::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {dir:?} — run `make artifacts`");
        return;
    }
    let eng = Engine::load(dir).expect("engine");
    let fed = fedavg::exper::mnist_fed(0.05, Partition::Iid, 7);
    let cfg = FedConfig {
        model: "mnist_2nn".into(),
        c: 0.1,
        e: 1,
        b: BatchSize::Fixed(10),
        lr: 0.1,
        rounds: 1,
        eval_every: 1,
        seed: 7,
        ..Default::default()
    };
    let with_fleet = |fleet: FleetConfig| ServerOptions { fleet, ..Default::default() };
    let run = |o: ServerOptions| federated::run(&eng, &fed, &cfg, o);
    let msg_of = |o: ServerOptions| format!("{:#}", run(o).unwrap_err());

    // robust order statistics have no partial-cohort meaning
    for spec in ["median", "trimmed:0.2"] {
        let mut o = with_fleet(async_cfg(FleetProfile::Uniform, 3, 0.9));
        o.agg.spec = spec.into();
        let msg = msg_of(o);
        assert!(msg.contains("order statistics"), "{spec}: {msg}");
        assert!(msg.contains("DESIGN.md §12"), "{spec}: {msg}");
        let mut o = with_fleet(semi_cfg(FleetProfile::Uniform, 0.0, 5.0, 0.9));
        o.agg.spec = spec.into();
        assert!(msg_of(o).contains("order statistics"), "{spec} semi-sync");
    }
    // secure-agg masks cancel only over one round's full cohort
    let mut o = with_fleet(async_cfg(FleetProfile::Uniform, 3, 0.9));
    o.secure_agg = true;
    let msg = msg_of(o);
    assert!(msg.contains("secure-agg"), "{msg}");
    assert!(msg.contains("partial buffer"), "{msg}");
    // the edge tier frames one combine per round
    let mut fleet = async_cfg(FleetProfile::Uniform, 3, 0.9);
    fleet.shards = 2;
    assert!(msg_of(with_fleet(fleet)).contains("--shards"));
    // async replaces the barrier — barrier knobs are refused
    let mut fleet = async_cfg(FleetProfile::Uniform, 3, 0.9);
    fleet.overselect = 0.3;
    assert!(msg_of(with_fleet(fleet)).contains("synchronous barrier"));
    // the two modes are alternatives, not composable
    let mut fleet = async_cfg(FleetProfile::Uniform, 3, 0.9);
    fleet.late_policy = LatePolicy::Discount;
    fleet.deadline_s = None;
    assert!(msg_of(with_fleet(fleet)).contains("alternative round modes"));
    // both modes schedule on the fleet's virtual clock
    let fleet = async_cfg(FleetProfile::Legacy, 3, 0.9);
    assert!(msg_of(with_fleet(fleet)).contains("fleet profile"));
    // lateness needs a deadline to be measured against
    let mut fleet = semi_cfg(FleetProfile::Uniform, 0.0, 5.0, 0.9);
    fleet.deadline_s = None;
    assert!(msg_of(with_fleet(fleet)).contains("nobody is late"));
    // decay domain
    let fleet = async_cfg(FleetProfile::Uniform, 3, 1.5);
    assert!(msg_of(with_fleet(fleet)).contains("--staleness-decay"));
    // ...and DP composes: clip+noise applies between combine and step,
    // the same seam the staleness scale uses (DESIGN.md §12)
    for fleet in [
        async_cfg(FleetProfile::Uniform, 3, 0.9),
        semi_cfg(FleetProfile::Uniform, 0.0, 5.0, 0.9),
    ] {
        let mut o = with_fleet(fleet);
        o.dp = Some(DpConfig { clip_norm: 1.0, sigma: 0.01 });
        o.eval_cap = Some(50);
        assert!(run(o).is_ok(), "central DP must compose with the async modes");
    }
}
