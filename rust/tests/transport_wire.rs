//! Integration: the transport subsystem without artifacts (always runs).
//!
//! Covers the DESIGN.md §6 invariants: for every registry pipeline the
//! planned wire size, the in-flight repr size, and the serialized frame
//! length agree and round-trip within the codec's error bound; the delta
//! downlink protocol (ack → patch → dense fallback → re-ack) reproduces
//! the server model bit-for-bit through its lossless path; and
//! error-feedback residuals advance only for clients whose updates were
//! actually aggregated — never for straggler drops.

use fedavg::comms::transport::{Transport, TransportConfig};
use fedavg::comms::wire::{decode_frame, Pipeline, HEADER_BYTES};
use fedavg::coordinator::schedule_round;
use fedavg::data::rng::Rng;

fn gauss(n: usize, seed: u64) -> Vec<f32> {
    let mut r = Rng::new(seed);
    (0..n).map(|_| r.gauss_f32()).collect()
}

/// Every pipeline shape the registry can express (delta and non-delta).
const ALL_PIPELINES: &[&str] = &[
    "dense",
    "q8",
    "q4",
    "q1",
    "topk:500",
    "topk:0.02",
    "topk:500|q8",
    "topk:0.02|q4",
    "delta",
    "delta|q8",
    "delta|topk:200",
    "delta|topk:200|q6",
];

#[test]
fn every_registry_pipeline_roundtrips_with_matching_wire_bytes() {
    let dim = 10_000;
    let base = gauss(dim, 1);
    let mut x = base.clone();
    // a realistic round-to-round change: ~5% of coords move
    for i in (0..dim).step_by(20) {
        x[i] += 0.5 + (i as f32) * 1e-4;
    }
    let (lo, hi) = x
        .iter()
        .fold((f32::INFINITY, f32::NEG_INFINITY), |(l, h), &v| (l.min(v), h.max(v)));

    for spec in ALL_PIPELINES {
        let p = Pipeline::parse(spec).unwrap();
        let mut rng = Rng::new(9);
        let b = p.has_delta().then_some((7u64, base.as_slice()));
        let frame = p.encode(&x, b, &mut rng).unwrap();

        // wire_bytes() exactly matches the encoded frame length, via
        // every route that computes it
        assert_eq!(
            p.measure(&x, b.map(|(_, m)| m)).unwrap(),
            frame.wire_bytes(),
            "{spec}: measure != frame length"
        );
        if !p.has_delta() {
            assert_eq!(p.plan_bytes(dim), frame.wire_bytes(), "{spec}: plan != frame length");
        }
        assert_eq!(
            frame.header().unwrap().expect_bytes(),
            frame.wire_bytes(),
            "{spec}: header-implied length mismatch"
        );

        // decode(encode(x)): dequantization error bounded per delivered
        // coordinate; undelivered coords fall back to 0 (sparse) or the
        // base (patch)
        let decoded = decode_frame(&frame.bytes, b.map(|(_, m)| m)).unwrap();
        assert_eq!(decoded.len(), dim, "{spec}");
        let bits = frame.header().unwrap().quant_bits;
        let bound = if bits > 0 {
            (hi - lo) / ((1u32 << bits) - 1) as f32 * 1.01
        } else {
            0.0
        };
        for i in 0..dim {
            let (a, d) = (x[i], decoded[i]);
            let delivered_ok = (a - d).abs() <= bound;
            let skipped_ok = if p.has_delta() {
                d.to_bits() == base[i].to_bits()
            } else {
                d == 0.0
            };
            assert!(
                delivered_ok || skipped_ok,
                "{spec} coord {i}: {a} decoded to {d} (bound {bound})"
            );
        }
        if p.lossless() {
            for i in 0..dim {
                assert_eq!(x[i].to_bits(), decoded[i].to_bits(), "{spec}: lossless drifted");
            }
        }
    }
}

#[test]
fn delta_downlink_after_dense_fallback_is_bit_exact() {
    // protocol walk: dense first contact → delta → store eviction →
    // dense fallback → delta again; the client-side reconstruction must
    // equal the server model bit-for-bit at every step
    let dim = 2000;
    let cfg = TransportConfig {
        up: None,
        down: Some(Pipeline::parse("delta").unwrap()),
        store_cap: 2,
    };
    let mut t = Transport::new(cfg, 2, dim, 5);
    let down = Pipeline::parse("delta").unwrap();
    let mut client_model: Option<Vec<f32>> = None; // client 0's cache
    let mut rng = Rng::new(11);

    let mut theta = gauss(dim, 3);
    let mut last_acked: Option<(u64, Vec<f32>)> = None;
    for round in 1..=8u64 {
        // model drifts sparsely each round
        for i in (0..dim).step_by(17) {
            theta[i] += (round as f32) * 0.01;
        }
        t.publish(round, &theta);
        // client 0 participates in rounds 1, 2, 6, 7, 8; rounds 3-5 of
        // absence age its ack (v2) out of the cap-2 store => round 6 must
        // be a dense fallback
        let participates = matches!(round, 1 | 2 | 6 | 7 | 8);
        if !participates {
            continue;
        }
        let bytes = t.downlink(0, round, &theta);
        let dense_frame = HEADER_BYTES + 4 * dim as u64;
        let expect_dense = matches!(round, 1 | 6);
        if expect_dense {
            assert_eq!(bytes, dense_frame, "round {round}: expected dense fallback");
        } else {
            assert!(bytes < dense_frame, "round {round}: expected a delta frame");
        }

        // simulate the client actually applying the frame
        let frame = if expect_dense {
            down.run_fallback(&theta, &mut rng).unwrap().to_frame()
        } else {
            let (v, base) = last_acked.as_ref().unwrap();
            down.encode(&theta, Some((*v, base.as_slice())), &mut rng).unwrap()
        };
        assert_eq!(frame.wire_bytes(), bytes, "round {round}: priced != encoded");
        let reconstructed = frame
            .decode(client_model.as_deref())
            .unwrap();
        for i in 0..dim {
            assert_eq!(
                reconstructed[i].to_bits(),
                theta[i].to_bits(),
                "round {round}: client model drifted at coord {i}"
            );
        }
        client_model = Some(reconstructed);
        last_acked = Some((round, theta.clone()));
    }
}

#[test]
fn straggler_dropped_clients_keep_their_residuals() {
    // the scheduler drops stragglers AFTER dispatch; their updates never
    // reach the uplink codec, so their error-feedback residuals must not
    // advance (the dropped mass was never aggregated — re-injecting it
    // next round would double-count)
    let dim = 300;
    let cfg = TransportConfig::parse(Some("topk:10"), None).unwrap();
    let mut t = Transport::new(cfg, 4, dim, 13);

    let update = |c: usize, r: u64| -> Vec<f32> {
        (0..dim).map(|i| ((i + c) as f32 * 0.1).sin() + r as f32 * 0.01).collect()
    };

    // round 1: dispatch 4, client 3 is the straggler (slowest), m=3
    let plan = schedule_round(3, None, &[(0, 1.0), (1, 2.0), (2, 3.0), (3, 50.0)]);
    assert_eq!(plan.completed, vec![0, 1, 2]);
    assert_eq!(plan.dropped, vec![3]);
    for &c in &plan.completed {
        let mut d = update(c, 1);
        t.encode_up(c, &mut d).unwrap();
    }
    let r3_after_1 = t.residual_norm(3);
    assert_eq!(r3_after_1, 0.0, "straggler-dropped client accumulated residual");
    let r0_after_1 = t.residual_norm(0);
    assert!(r0_after_1 > 0.0, "aggregated client has no residual");

    // round 2: client 3 straggles again — still untouched
    let plan = schedule_round(2, Some(4.0), &[(0, 1.0), (3, 9.0), (2, 2.0)]);
    assert!(plan.dropped.contains(&3));
    for &c in &plan.completed {
        let mut d = update(c, 2);
        t.encode_up(c, &mut d).unwrap();
    }
    assert_eq!(t.residual_norm(3), 0.0);

    // round 3: client 3 finally completes; only now does its residual
    // move, and exactly once
    let mut d = update(3, 3);
    let folded = d.clone(); // residual was zero, so fold_in adds nothing
    t.encode_up(3, &mut d).unwrap();
    let resid = t.residual_norm(3);
    assert!(resid > 0.0);
    // conservation: ||folded - delivered|| == residual norm
    let err: f64 = folded
        .iter()
        .zip(&d)
        .map(|(a, b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        .sqrt();
    assert!((err - resid).abs() < 1e-3, "{err} vs {resid}");
}

#[test]
fn scheduler_pricing_equals_encoded_bytes_for_every_pipeline() {
    // the no-drift acceptance invariant, pipeline by pipeline: what the
    // scheduler would price an uplink at before training equals what the
    // encoder later produces
    let dim = 5000;
    for spec in ALL_PIPELINES {
        let p = Pipeline::parse(spec).unwrap();
        if p.has_delta() {
            continue; // delta is downlink-only; priced at encode time
        }
        let cfg = TransportConfig::parse(Some(spec), None).unwrap();
        let mut t = Transport::new(cfg, 1, dim, 21);
        let priced = t.up_plan_bytes();
        let mut d = gauss(dim, 22);
        let encoded = t.encode_up(0, &mut d).unwrap();
        assert_eq!(priced, encoded, "{spec}: estimate/actual drift");
    }
}

#[test]
fn transport_config_parse_validates_directions() {
    assert!(TransportConfig::parse(Some("delta"), None).is_err(), "delta uplink");
    assert!(TransportConfig::parse(None, Some("delta|q8")).is_ok());
    assert!(TransportConfig::parse(Some("nope"), None).is_err());
    // a sparsifying downlink without a delta base would zero every
    // unsent coordinate of the broadcast model
    assert!(TransportConfig::parse(None, Some("topk:0.01")).is_err(), "topk downlink sans delta");
    assert!(TransportConfig::parse(None, Some("delta|topk:0.01")).is_ok());
    let t = TransportConfig::parse(Some("topk:0.01|q8"), Some("delta")).unwrap();
    assert!(t.active());
    assert!(!TransportConfig::default().active());
}
