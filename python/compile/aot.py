"""AOT compiler: lower every model entry point to HLO **text** artifacts.

Interchange format is HLO text, NOT ``lowered.compile().serialize()`` —
the rust side's xla_extension 0.5.1 rejects jax>=0.5 serialized protos
(64-bit instruction ids); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Outputs, per model M / entry E:  ``<outdir>/M.E.hlo.txt``
plus ``<outdir>/manifest.json`` describing shapes/dtypes/param counts for
the rust runtime, and ``<outdir>/.stamp`` for Makefile freshness.

Usage:  python -m compile.aot --outdir ../artifacts [--models a,b,...]
"""

import argparse
import hashlib
import json
import os
import time

import jax
from jax._src.lib import xla_client as xc

from compile.model import MODELS, batch_specs, build_entries

# word_lstm is heavy to lower/compile; excluded from the default set and
# pulled in by `make artifacts-full` / --models word_lstm when needed.
DEFAULT_MODELS = ["mnist_2nn", "mnist_cnn", "shakespeare_lstm", "cifar_cnn"]


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(name: str, outdir: str) -> dict:
    spec = MODELS[name]
    param_count, entries = build_entries(spec)
    meta = {
        "name": name,
        "param_count": param_count,
        "kind": spec.kind,
        "x_dim": spec.x_dim,
        "num_classes": spec.num_classes,
        "step_batches": list(spec.step_batches),
        "acc_batch": spec.acc_batch,
        "entries": {},
    }
    for entry, (fn, args) in entries.items():
        t0 = time.time()
        text = to_hlo_text(jax.jit(fn).lower(*args))
        fname = f"{name}.{entry}.hlo.txt"
        path = os.path.join(outdir, fname)
        with open(path, "w") as f:
            f.write(text)
        meta["entries"][entry] = {
            "file": fname,
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
            "bytes": len(text),
        }
        print(
            f"  {name}.{entry}: {len(text) / 1e6:.2f} MB "
            f"({time.time() - t0:.1f}s)"
        )
    return meta


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument(
        "--models",
        default=",".join(DEFAULT_MODELS),
        help="comma-separated subset of: " + ",".join(MODELS),
    )
    # kept for Makefile compatibility with single-file invocations
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()
    outdir = os.path.dirname(args.out) if args.out else args.outdir
    os.makedirs(outdir, exist_ok=True)

    names = [n.strip() for n in args.models.split(",") if n.strip()]
    manifest = {"models": {}}
    # merge with any existing manifest so subsets don't clobber other models
    mpath = os.path.join(outdir, "manifest.json")
    if os.path.exists(mpath):
        with open(mpath) as f:
            manifest = json.load(f)
    for name in names:
        print(f"lowering {name} ...")
        manifest["models"][name] = lower_model(name, outdir)
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    with open(os.path.join(outdir, ".stamp"), "w") as f:
        f.write(str(time.time()))
    print(f"manifest: {mpath}")


if __name__ == "__main__":
    main()
