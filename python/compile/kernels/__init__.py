"""L1 Pallas kernels — the compute hot-spot of every model in the zoo.

All kernels are authored for the TPU memory hierarchy (VMEM blocks, MXU-
shaped tiles) but lowered with ``interpret=True`` so the resulting HLO is
plain ops executable by the CPU PJRT client the rust runtime uses.  Each
kernel has a pure-jnp oracle in :mod:`compile.kernels.ref` and is verified
against it by ``python/tests/test_kernels.py``.
"""

from compile.kernels.matmul import matmul_fused
from compile.kernels.elementwise import sgd_update
from compile.kernels.lstm import lstm_cell
from compile.kernels.softmax import softmax_xent

__all__ = ["matmul_fused", "sgd_update", "lstm_cell", "softmax_xent"]
