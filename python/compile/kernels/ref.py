"""Pure-jnp correctness oracles for every Pallas kernel.

These are the ground truth the pytest suite (``python/tests``) checks the
kernels against, both pointwise (``assert_allclose``) and through
``jax.grad`` (custom-VJP vs autodiff-of-reference).
"""

import jax
import jax.numpy as jnp

_ACTS = {
    "none": lambda z: z,
    "relu": lambda z: jnp.maximum(z, 0.0),
    "tanh": jnp.tanh,
    "sigmoid": jax.nn.sigmoid,
}


def matmul_fused_ref(x, w, b, act: str = "none"):
    """act(x @ w + b) — plain jnp."""
    return _ACTS[act](x @ w + b)


def sgd_update_ref(theta, grad, lr):
    """theta - lr * grad — plain jnp."""
    return theta - lr * grad


def lstm_cell_ref(z, c):
    """Fused LSTM cell (gate layout [i|f|g|o]) — plain jnp."""
    hidden = z.shape[1] // 4
    i = jax.nn.sigmoid(z[:, 0 * hidden : 1 * hidden])
    f = jax.nn.sigmoid(z[:, 1 * hidden : 2 * hidden])
    g = jnp.tanh(z[:, 2 * hidden : 3 * hidden])
    o = jax.nn.sigmoid(z[:, 3 * hidden : 4 * hidden])
    cn = f * c + i * g
    return o * jnp.tanh(cn), cn


def softmax_xent_ref(logits, labels):
    """Per-row CE loss; negative labels produce exactly 0 loss."""
    lse = jax.scipy.special.logsumexp(logits, axis=1)
    v = logits.shape[1]
    safe = jnp.clip(labels, 0, v - 1)
    picked = jnp.take_along_axis(logits, safe[:, None], axis=1)[:, 0]
    return jnp.where(labels >= 0, lse - picked, 0.0)
