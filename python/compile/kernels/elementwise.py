"""Fused SGD update (axpy) Pallas kernel.

``theta' = theta - lr * grad`` over the flat parameter vector.  This is the
server/client hot loop's only elementwise pass over the full model; fusing
it keeps every step executable down to a single streaming traversal of the
parameters (memory-bandwidth bound by construction).

The learning rate arrives as a *runtime* ``f32[1]`` input (broadcast to
every block), so one compiled executable serves the paper's entire
11-13-point learning-rate grid.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 1-D VMEM block: 64k f32 = 256 KiB per operand, 3 operands ≈ 768 KiB —
# comfortably inside a 16 MiB VMEM budget with double buffering.
_BLOCK = 65536


def _rup(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _axpy_kernel(lr_ref, t_ref, g_ref, o_ref):
    o_ref[...] = t_ref[...] - lr_ref[0] * g_ref[...]


def sgd_update(theta, grad, lr):
    """theta - lr * grad, fused; theta/grad are flat f32[P], lr is scalar."""
    (p,) = theta.shape
    assert grad.shape == (p,)
    block = min(_BLOCK, _rup(p, 128))
    pp = _rup(p, block)
    tp = jnp.pad(theta, (0, pp - p))
    gp = jnp.pad(grad, (0, pp - p))
    lr_arr = jnp.asarray(lr, dtype=jnp.float32).reshape(1)

    out = pl.pallas_call(
        _axpy_kernel,
        grid=(pp // block,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((pp,), jnp.float32),
        interpret=True,
    )(lr_arr, tp, gp)
    return out[:p]
