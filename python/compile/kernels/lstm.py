"""Fused LSTM cell Pallas kernel with custom VJP.

Given the pre-projected gate activations ``z = [x, h] @ W + b`` (computed
by :func:`compile.kernels.matmul.matmul_fused`), this kernel fuses the
four gate nonlinearities and the state update into one VMEM pass:

    i = sigmoid(z[:,   0:H])      f = sigmoid(z[:,  H:2H])
    g = tanh   (z[:, 2H:3H])      o = sigmoid(z[:, 3H:4H])
    c' = f * c + i * g            h' = o * tanh(c')

Gate layout is [i | f | g | o] along the feature axis (columns of W).

The backward pass recomputes the (cheap, elementwise) gates from the saved
``(z, c)`` residuals in plain jnp — recompute-over-store, the same trade
the fused-cell kernels in cuDNN make.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_SUBLANE = 8


def _rup(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _cell_kernel(z_ref, c_ref, h_ref, cn_ref, *, hidden: int):
    z = z_ref[...]
    c = c_ref[...]
    i = jax.nn.sigmoid(z[:, 0 * hidden : 1 * hidden])
    f = jax.nn.sigmoid(z[:, 1 * hidden : 2 * hidden])
    g = jnp.tanh(z[:, 2 * hidden : 3 * hidden])
    o = jax.nn.sigmoid(z[:, 3 * hidden : 4 * hidden])
    cn = f * c + i * g
    h_ref[...] = o * jnp.tanh(cn)
    cn_ref[...] = cn


def _gates(z, c, hidden):
    i = jax.nn.sigmoid(z[:, 0 * hidden : 1 * hidden])
    f = jax.nn.sigmoid(z[:, 1 * hidden : 2 * hidden])
    g = jnp.tanh(z[:, 2 * hidden : 3 * hidden])
    o = jax.nn.sigmoid(z[:, 3 * hidden : 4 * hidden])
    cn = f * c + i * g
    return i, f, g, o, cn


@jax.custom_vjp
def lstm_cell(z, c):
    """(h', c') from pre-activations z: f32[B, 4H] and cell state c: f32[B, H]."""
    return _cell_pallas(z, c)


def _cell_pallas(z, c):
    b, h4 = z.shape
    hidden = h4 // 4
    assert h4 == 4 * hidden and c.shape == (b, hidden)
    # Block over batch rows only: each block sees all 4H gate columns so the
    # i/f/g/o split happens entirely in VMEM.  4H=1024 f32 rows are 4 KiB —
    # 8-row blocks keep the working set tiny.
    bb = min(_rup(b, _SUBLANE), 64)
    bp = _rup(b, bb)
    zp = jnp.pad(z, ((0, bp - b), (0, 0)))
    cp = jnp.pad(c, ((0, bp - b), (0, 0)))

    import functools

    h_out, c_out = pl.pallas_call(
        functools.partial(_cell_kernel, hidden=hidden),
        grid=(bp // bb,),
        in_specs=[
            pl.BlockSpec((bb, h4), lambda i: (i, 0)),
            pl.BlockSpec((bb, hidden), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, hidden), lambda i: (i, 0)),
            pl.BlockSpec((bb, hidden), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, hidden), jnp.float32),
            jax.ShapeDtypeStruct((bp, hidden), jnp.float32),
        ],
        interpret=True,
    )(zp, cp)
    return h_out[:b], c_out[:b]


def _cell_fwd(z, c):
    out = _cell_pallas(z, c)
    return out, (z, c)


def _cell_bwd(res, grads):
    z, c = res
    gh, gc = grads
    hidden = z.shape[1] // 4
    i, f, g, o, cn = _gates(z, c, hidden)
    tc = jnp.tanh(cn)
    do = gh * tc
    dcn = gc + gh * o * (1.0 - tc * tc)
    di = dcn * g
    df = dcn * c
    dg = dcn * i
    dc = dcn * f
    dz = jnp.concatenate(
        [
            di * i * (1.0 - i),
            df * f * (1.0 - f),
            dg * (1.0 - g * g),
            do * o * (1.0 - o),
        ],
        axis=1,
    )
    return dz, dc


lstm_cell.defvjp(_cell_fwd, _cell_bwd)
