"""Fused numerically-stable softmax cross-entropy Pallas kernel + VJP.

Per row: ``loss = logsumexp(logits) - logits[label]``, computed in one
VMEM pass (max, exp-sum, gather fused).  Labels ride along as an int32
column; out-of-vocab padding labels (-1 or any negative) produce loss 0,
letting callers express padded batches purely through labels/weights.

Backward: ``d logits = g * (softmax(logits) - onehot(label))`` recomputed
from the (logits, labels) residuals in plain jnp.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_SUBLANE = 8
_NEG = -1e30


def _rup(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _xent_kernel(l_ref, y_ref, o_ref, *, vocab: int):
    logits = l_ref[...]  # (bb, Vp) — padded cols already hold _NEG
    y = y_ref[...]  # (bb,)
    m = jnp.max(logits, axis=1)
    lse = m + jnp.log(jnp.sum(jnp.exp(logits - m[:, None]), axis=1))
    safe_y = jnp.clip(y, 0, vocab - 1)
    picked = jnp.take_along_axis(logits, safe_y[:, None], axis=1)[:, 0]
    loss = lse - picked
    o_ref[...] = jnp.where(y >= 0, loss, 0.0)


@jax.custom_vjp
def softmax_xent(logits, labels):
    """Per-row cross-entropy loss: f32[R, V], int32[R] -> f32[R]."""
    return _xent_pallas(logits, labels)


def _xent_pallas(logits, labels):
    r, v = logits.shape
    assert labels.shape == (r,)
    bb = min(_rup(r, _SUBLANE), 128)
    rp = _rup(r, bb)
    vp = _rup(v, 128)
    lp = jnp.pad(logits, ((0, rp - r), (0, vp - v)), constant_values=_NEG)
    # Padded rows get label -1 => loss 0.
    yp = jnp.pad(labels.astype(jnp.int32), (0, rp - r), constant_values=-1)

    out = pl.pallas_call(
        functools.partial(_xent_kernel, vocab=v),
        grid=(rp // bb,),
        in_specs=[
            pl.BlockSpec((bb, vp), lambda i: (i, 0)),
            pl.BlockSpec((bb,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((rp,), jnp.float32),
        interpret=True,
    )(lp, yp)
    return out[:r]


def _xent_fwd(logits, labels):
    return _xent_pallas(logits, labels), (logits, labels)


def _xent_bwd(res, g):
    logits, labels = res
    v = logits.shape[1]
    p = jax.nn.softmax(logits, axis=1)
    valid = labels >= 0
    onehot = jax.nn.one_hot(jnp.clip(labels, 0, v - 1), v, dtype=logits.dtype)
    dlogits = (g * valid)[:, None] * (p - onehot)
    return dlogits, None


softmax_xent.defvjp(_xent_fwd, _xent_bwd)
