"""Fused tiled matmul + bias + activation Pallas kernel with custom VJP.

This is the hot-spot kernel of the whole model zoo: every fully-connected
layer, every LSTM gate projection, and (via im2col) every convolution in
the paper's five architectures bottoms out here.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid tiles the
output into ``(bm, bn)`` VMEM blocks with a sequential reduction over
``bk``-sized K panels — the classic MXU-systolic schedule.  Block sizes
are capped at 128 (the MXU edge) and adapt downward for small problem
sizes so the interpret-mode CPU path does not pay padding flops.  The
K-accumulation happens in the f32 output block itself (revolving in VMEM),
so no extra scratch is required.

Backward pass is expressed with the *same* kernel (two more tiled matmuls
for dx and dW), wired up through ``jax.custom_vjp`` because
``pallas_call`` is not differentiable on its own.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Activation registry: name -> (apply, grad-from-activated-output).
# The grad form is chosen so the backward pass only needs the *activated*
# output as residual (never the pre-activation), halving residual memory.
_ACTS = {
    "none": (lambda z: z, lambda y: jnp.ones_like(y)),
    "relu": (lambda z: jnp.maximum(z, 0.0), lambda y: (y > 0.0).astype(y.dtype)),
    "tanh": (jnp.tanh, lambda y: 1.0 - y * y),
    "sigmoid": (jax.nn.sigmoid, lambda y: y * (1.0 - y)),
}

# MXU edge length; tiled blocks never exceed this in any dimension.
_MXU = 128
# Sublane quantum: block rows are padded to a multiple of this.
_SUBLANE = 8
# Single-block budget: if the whole (padded) problem fits in this many
# bytes of VMEM (x + w + out blocks, f32), run it as ONE grid step — no
# K-loop, no revolving output. 12 MiB of a 16 MiB/core VMEM leaves room
# for the bias row and control. This is the §Perf L1 fix: small matmuls
# (conv im2col panels, LSTM gate projections) previously paid up to 20x
# padding waste from forcing 128-edge tiles.
_VMEM_BUDGET = 12 * 1024 * 1024


def _rup(x: int, m: int) -> int:
    """Round ``x`` up to a multiple of ``m``."""
    return ((x + m - 1) // m) * m


def _block_shape(m: int, k: int, n: int):
    """Pick (bm, bk, bn): the largest blocks that fit the VMEM budget.

    Whole-problem single block when it fits (padded to sublane quanta);
    otherwise repeatedly halve the largest dimension (never below the MXU
    edge) until the x/w/out working set fits. Maximizing block volume
    minimizes grid steps — which on TPU means fewer HBM<->VMEM round
    trips, and on the interpret-mode CPU path means fewer dynamic-slice
    loop iterations (the §Perf L1 fix).
    """
    dims = [_rup(m, _SUBLANE), _rup(k, _SUBLANE), _rup(n, _SUBLANE)]

    def fits(d):
        return 4 * (d[0] * d[1] + d[1] * d[2] + d[0] * d[2]) <= _VMEM_BUDGET

    while not fits(dims):
        i = max(range(3), key=lambda j: dims[j])
        if dims[i] <= _MXU:
            break  # 3 MXU-edge blocks always fit
        dims[i] = max(_rup(dims[i] // 2, _SUBLANE), _MXU)
    return dims[0], dims[1], dims[2]


def _mm_kernel(x_ref, w_ref, b_ref, o_ref, *, k_steps: int, act: str):
    """One (i, j, k) grid step: accumulate an MXU panel into the out block."""

    @pl.when(pl.program_id(2) == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _epilogue():
        apply, _ = _ACTS[act]
        o_ref[...] = apply(o_ref[...] + b_ref[...])


def _matmul_pallas(x, w, b, act: str):
    """Raw (non-differentiable) fused matmul: act(x @ w + b).

    x: f32[M, K]   w: f32[K, N]   b: f32[N]   ->   f32[M, N]
    Arbitrary shapes; inputs are zero-padded to block multiples and the
    output is sliced back.  Zero padding is exact for matmul (rows/cols of
    zeros contribute nothing) and the bias/activation epilogue only ever
    lands in the sliced-away region.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"matmul_fused: inner dims {k} != {k2}"
    assert b.shape == (n,), f"matmul_fused: bias {b.shape} != ({n},)"
    bm, bk, bn = _block_shape(m, k, n)
    mp, kp, np_ = _rup(m, bm), _rup(k, bk), _rup(n, bn)

    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    bp = jnp.pad(b, (0, np_ - n)).reshape(1, np_)

    k_steps = kp // bk
    out = pl.pallas_call(
        functools.partial(_mm_kernel, k_steps=k_steps, act=act),
        grid=(mp // bm, np_ // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def matmul_fused(x, w, b, act: str = "none"):
    """``act(x @ w + b)`` as a single fused Pallas kernel.

    Differentiable w.r.t. ``x``, ``w`` and ``b``; the backward pass reuses
    the same tiled kernel for the two transposed matmuls.
    """
    return _matmul_pallas(x, w, b, act)


def _mm_fwd(x, w, b, act):
    y = _matmul_pallas(x, w, b, act)
    # Residuals: inputs + *activated* output (enough for every act's grad).
    return y, (x, w, y)


def _mm_bwd(act, res, g):
    x, w, y = res
    _, dact = _ACTS[act]
    dz = g * dact(y)
    zeros_k = jnp.zeros((x.shape[1],), dtype=x.dtype)
    zeros_n = jnp.zeros((w.shape[1],), dtype=w.dtype)
    dx = _matmul_pallas(dz, w.T, zeros_k, "none")
    dw = _matmul_pallas(x.T, dz, zeros_n, "none")
    db = jnp.sum(dz, axis=0)
    return dx, dw, db


matmul_fused.defvjp(_mm_fwd, _mm_bwd)
