"""MNIST CNN — the paper's convolutional model (§3).

Two 5x5 conv layers (32 then 64 channels, each followed by 2x2 max pool),
an FC layer with 512 units + ReLU, and a softmax output layer:
1,663,370 parameters, matching the paper exactly.

Input arrives flattened (f32[B, 784]) and is reshaped to NHWC here so the
rust data plane stays shape-oblivious across the MNIST models.
"""

import jax
import jax.numpy as jnp

from compile.kernels import softmax_xent
from compile.models import common

NUM_CLASSES = 10
PARAM_COUNT = 1_663_370


def init(key):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "conv1": common.conv_params(k1, 5, 5, 1, 32),
        "conv2": common.conv_params(k2, 5, 5, 32, 64),
        "fc": common.dense_params(k3, 7 * 7 * 64, 512),
        "out": common.dense_params(k4, 512, NUM_CLASSES),
    }


def apply(params, x):
    b = x.shape[0]
    img = x.reshape(b, 28, 28, 1)
    h = common.conv2d(params["conv1"], img, "relu")
    h = common.maxpool2(h)  # 14x14x32
    h = common.conv2d(params["conv2"], h, "relu")
    h = common.maxpool2(h)  # 7x7x64
    h = h.reshape(b, 7 * 7 * 64)
    h = common.dense(params["fc"], h, "relu")
    return common.dense(params["out"], h, "none")


def loss_and_metrics(params, x, y, w):
    logits = apply(params, x)
    losses = softmax_xent(logits, y)
    correct = (jnp.argmax(logits, axis=1) == y).astype(jnp.float32)
    return jnp.sum(w * losses), jnp.sum(w * correct), jnp.sum(w)
