"""The paper's two recurrent models (§3).

``shakespeare_lstm`` — character-level: embed-8 → 2 x LSTM-256 → softmax
over the character vocabulary, unroll length 80.  With our 90-symbol
synthetic-playwright vocabulary this is 820,522 parameters (the paper's
866,578 implies a slightly larger vocab it never states; documented in
DESIGN.md).

``word_lstm`` — the large-scale next-word model: 10k-word vocabulary,
input and output embeddings of dimension 192 (co-trained, untied),
LSTM-256, unroll length 10.  4,359,120 parameters vs the paper's
4,950,544 (exact head wiring unstated; documented).

Both take ``x:int32[B,T]`` token ids, ``y:int32[B,T]`` next-token targets
and ``w:f32[B,T]`` per-token weights (0 on padding), and report per-token
weighted CE / accuracy — exactly the paper's accuracy metric ("fraction
of the data where the highest predicted probability was on the correct
next word").
"""

import jax
import jax.numpy as jnp

from compile.kernels import matmul_fused, softmax_xent
from compile.models import common

CHAR_VOCAB = 90
CHAR_EMBED = 8
CHAR_HIDDEN = 256
CHAR_UNROLL = 80
SHAKESPEARE_PARAM_COUNT = 820_522

WORD_VOCAB = 10_000
WORD_EMBED = 192
WORD_HIDDEN = 256
WORD_UNROLL = 10
WORD_PARAM_COUNT = 4_359_120


def _embed_params(key, vocab, dim):
    return {"e": jax.random.normal(key, (vocab, dim), jnp.float32) * 0.1}


def _lm_metrics(logits_flat, y, w):
    yf = y.reshape(-1)
    wf = w.reshape(-1)
    losses = softmax_xent(logits_flat, yf)
    correct = (jnp.argmax(logits_flat, axis=1) == yf).astype(jnp.float32)
    return jnp.sum(wf * losses), jnp.sum(wf * correct), jnp.sum(wf)


# ---------------------------------------------------------------- char LSTM


def shakespeare_init(key):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "embed": _embed_params(k1, CHAR_VOCAB, CHAR_EMBED),
        "lstm1": common.lstm_params(k2, CHAR_EMBED, CHAR_HIDDEN),
        "lstm2": common.lstm_params(k3, CHAR_HIDDEN, CHAR_HIDDEN),
        "out": common.dense_params(k4, CHAR_HIDDEN, CHAR_VOCAB),
    }


def shakespeare_apply(params, x):
    """x: int32[B,T] -> logits f32[B*T, V] (time-major flattening)."""
    b, t = x.shape
    emb = params["embed"]["e"][x]  # [B,T,E]
    xs = jnp.transpose(emb, (1, 0, 2))  # [T,B,E]
    hs = common.lstm_layer(params["lstm1"], xs)
    hs = common.lstm_layer(params["lstm2"], hs)
    flat = hs.reshape(t * b, CHAR_HIDDEN)
    logits = matmul_fused(flat, params["out"]["w"], params["out"]["b"], "none")
    return logits, (b, t)


def shakespeare_loss_and_metrics(params, x, y, w):
    logits, (b, t) = shakespeare_apply(params, x)
    # logits are [T*B, V]; reorder targets to match time-major flattening.
    yt = jnp.transpose(y, (1, 0))
    wt = jnp.transpose(w, (1, 0))
    return _lm_metrics(logits, yt, wt)


# ---------------------------------------------------------------- word LSTM


def word_init(key):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "embed_in": _embed_params(k1, WORD_VOCAB, WORD_EMBED),
        "lstm": common.lstm_params(k2, WORD_EMBED, WORD_HIDDEN),
        "proj": common.dense_params(k3, WORD_HIDDEN, WORD_EMBED),
        "embed_out": _embed_params(k4, WORD_VOCAB, WORD_EMBED),
        "out_bias": {"b": jnp.zeros((WORD_VOCAB,), jnp.float32)},
    }


def word_apply(params, x):
    b, t = x.shape
    emb = params["embed_in"]["e"][x]
    xs = jnp.transpose(emb, (1, 0, 2))
    hs = common.lstm_layer(params["lstm"], xs)
    flat = hs.reshape(t * b, WORD_HIDDEN)
    proj = matmul_fused(flat, params["proj"]["w"], params["proj"]["b"], "tanh")
    logits = matmul_fused(
        proj, params["embed_out"]["e"].T, params["out_bias"]["b"], "none"
    )
    return logits, (b, t)


def word_loss_and_metrics(params, x, y, w):
    logits, (b, t) = word_apply(params, x)
    yt = jnp.transpose(y, (1, 0))
    wt = jnp.transpose(w, (1, 0))
    return _lm_metrics(logits, yt, wt)
