"""L2 model zoo — the paper's five architectures (McMahan et al. §3).

Every model is a pair of pure functions over a parameter pytree:

    init(rng)                 -> params
    loss_and_metrics(params, x, y, w) -> (weighted_loss_sum, weighted_correct_sum, weight_sum)

with all dense compute routed through the L1 Pallas kernels.  The AOT
entry-point builders in :mod:`compile.model` wrap these into the four HLO
executables (init / step / gradacc / eval) the rust coordinator drives.
"""

from compile.models import cifar, cnn, lstm_models, mlp

__all__ = ["mlp", "cnn", "lstm_models", "cifar"]
