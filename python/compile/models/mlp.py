"""MNIST 2NN — the paper's multilayer perceptron (§3).

784–200–200–10 with ReLU activations: 199,210 parameters, matching the
paper exactly.  Input arrives flattened (f32[B, 784]).
"""

import jax
import jax.numpy as jnp

from compile.kernels import softmax_xent
from compile.models import common

NUM_CLASSES = 10
INPUT_DIM = 784
HIDDEN = 200
PARAM_COUNT = 199_210


def init(key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "fc1": common.dense_params(k1, INPUT_DIM, HIDDEN),
        "fc2": common.dense_params(k2, HIDDEN, HIDDEN),
        "out": common.dense_params(k3, HIDDEN, NUM_CLASSES),
    }


def apply(params, x):
    h = common.dense(params["fc1"], x, "relu")
    h = common.dense(params["fc2"], h, "relu")
    return common.dense(params["out"], h, "none")


def loss_and_metrics(params, x, y, w):
    """(Σ w·CE, Σ w·correct, Σ w) over a weight-padded batch."""
    logits = apply(params, x)
    losses = softmax_xent(logits, y)
    correct = (jnp.argmax(logits, axis=1) == y).astype(jnp.float32)
    return jnp.sum(w * losses), jnp.sum(w * correct), jnp.sum(w)
