"""Shared layers and initializers for the model zoo.

Conventions
-----------
* Parameters are plain dicts of f32 arrays; the AOT layer flattens them
  with ``jax.flatten_util.ravel_pytree`` so the rust coordinator only ever
  sees one flat f32 vector.
* Convolutions are expressed as im2col (``conv_general_dilated_patches``,
  whose feature axis is **channel-major**: (cin, kh, kw)) followed by the
  fused Pallas matmul, so the L1 kernel sits on the conv hot path too.
  Conv weights are therefore stored already-reshaped as
  ``[cin*kh*kw, cout]`` with channel-major row order.
"""

import jax
import jax.numpy as jnp

from compile.kernels import matmul_fused


def glorot(key, shape, fan_in, fan_out):
    """Glorot/Xavier uniform — TF-era default, matching the paper's stack."""
    limit = jnp.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, jnp.float32, -limit, limit)


def dense_params(key, d_in, d_out):
    return {
        "w": glorot(key, (d_in, d_out), d_in, d_out),
        "b": jnp.zeros((d_out,), jnp.float32),
    }


def dense(p, x, act="none"):
    return matmul_fused(x, p["w"], p["b"], act)


def conv_params(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    fan_out = kh * kw * cout
    return {
        # channel-major row order to match conv_general_dilated_patches.
        "w": glorot(key, (cin * kh * kw, cout), fan_in, fan_out),
        "b": jnp.zeros((cout,), jnp.float32),
    }


def conv2d(p, x, act="relu", kh=5, kw=5):
    """SAME conv via im2col + fused Pallas matmul.  x: f32[B,H,W,Cin]."""
    b, h, w_, cin = x.shape
    cout = p["w"].shape[1]
    patches = jax.lax.conv_general_dilated_patches(
        x, (kh, kw), (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )  # [B,H,W,cin*kh*kw], channel-major
    mat = patches.reshape(b * h * w_, cin * kh * kw)
    out = matmul_fused(mat, p["w"], p["b"], act)
    return out.reshape(b, h, w_, cout)


def maxpool2(x):
    """2x2 max pool, stride 2 (paper's pooling everywhere)."""
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def lstm_params(key, d_in, hidden):
    """One LSTM layer: combined [x|h] -> 4H projection, gate order [i|f|g|o].

    Forget-gate bias starts at 1.0 (standard practice the paper's TF stack
    used by default) so gradients flow at init.
    """
    w = glorot(key, (d_in + hidden, 4 * hidden), d_in + hidden, 4 * hidden)
    b = jnp.zeros((4 * hidden,), jnp.float32)
    b = b.at[hidden : 2 * hidden].set(1.0)
    return {"w": w, "b": b}


def lstm_layer(p, xs):
    """Scan an LSTM over time.  xs: f32[T,B,D] -> hs: f32[T,B,H]."""
    from compile.kernels import lstm_cell

    hidden = p["w"].shape[1] // 4
    batch = xs.shape[1]
    h0 = jnp.zeros((batch, hidden), jnp.float32)
    c0 = jnp.zeros((batch, hidden), jnp.float32)

    def step(carry, x_t):
        h, c = carry
        z = matmul_fused(jnp.concatenate([x_t, h], axis=1), p["w"], p["b"], "none")
        h2, c2 = lstm_cell(z, c)
        return (h2, c2), h2

    (_, _), hs = jax.lax.scan(step, (h0, c0), xs)
    return hs


def count_params(params) -> int:
    leaves = [x for x in jax.tree_util.tree_leaves(params) if hasattr(x, "size")]
    return int(sum(x.size for x in leaves if x.dtype == jnp.float32))
