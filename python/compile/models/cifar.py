"""CIFAR CNN — the TensorFlow-tutorial architecture the paper uses (§3).

Two 5x5x64 conv layers (each + 2x2 max pool), FC-384, FC-192, linear-10:
1,068,298 parameters ("about 10^6" in the paper).  We omit the tutorial's
local-response-normalization layers (deprecated even by 2016 and absent
from the paper's description); documented in DESIGN.md.

Input is the paper's preprocessed 24x24x3 crop, flattened to f32[B, 1728].
"""

import jax
import jax.numpy as jnp

from compile.kernels import softmax_xent
from compile.models import common

NUM_CLASSES = 10
SIDE = 24
INPUT_DIM = SIDE * SIDE * 3
PARAM_COUNT = 1_068_298


def init(key):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    return {
        "conv1": common.conv_params(k1, 5, 5, 3, 64),
        "conv2": common.conv_params(k2, 5, 5, 64, 64),
        "fc1": common.dense_params(k3, 6 * 6 * 64, 384),
        "fc2": common.dense_params(k4, 384, 192),
        "out": common.dense_params(k5, 192, NUM_CLASSES),
    }


def apply(params, x):
    b = x.shape[0]
    img = x.reshape(b, SIDE, SIDE, 3)
    h = common.conv2d(params["conv1"], img, "relu")
    h = common.maxpool2(h)  # 12x12x64
    h = common.conv2d(params["conv2"], h, "relu")
    h = common.maxpool2(h)  # 6x6x64
    h = h.reshape(b, 6 * 6 * 64)
    h = common.dense(params["fc1"], h, "relu")
    h = common.dense(params["fc2"], h, "relu")
    return common.dense(params["out"], h, "none")


def loss_and_metrics(params, x, y, w):
    logits = apply(params, x)
    losses = softmax_xent(logits, y)
    correct = (jnp.argmax(logits, axis=1) == y).astype(jnp.float32)
    return jnp.sum(w * losses), jnp.sum(w * correct), jnp.sum(w)
