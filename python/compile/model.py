"""L2 entry-point builders: model zoo -> the four AOT executables.

For every model the rust coordinator gets:

    init    : (seed i32[])                  -> theta f32[P]
    step_bB : (theta, x, y, w, lr f32[])    -> theta'            (one SGD step
              on the weighted-mean loss; padding rows carry w=0)
    gradacc : (theta, x, y, w)              -> sum_i w_i * grad_i  f32[P]
              (linear in examples => rust chunk-sums reproduce exact full-
              batch B=inf gradients for FedSGD at any client size)
    apply   : (theta, g, lr)                -> theta - lr * g    (Pallas axpy)
    eval_bB : (theta, x, y, w)              -> f32[3] = (sum w*loss,
                                                sum w*correct, sum w)

Parameters cross the boundary as ONE flat f32 vector (ravel_pytree), so
the rust server's averaging math is shape-oblivious.
"""

import dataclasses
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from compile.kernels import sgd_update
from compile.models import cifar, cnn, lstm_models, mlp


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Static description of one model family + its AOT batch capacities."""

    name: str
    init_fn: Callable
    loss_fn: Callable  # (params, x, y, w) -> (wloss, wcorrect, wsum)
    kind: str  # "image" | "tokens"
    x_dim: int  # feature dim (image) or unroll length T (tokens)
    num_classes: int  # classes (image) or vocab (tokens)
    step_batches: Tuple[int, ...]
    acc_batch: int  # capacity used by gradacc + eval


MODELS: Dict[str, ModelSpec] = {
    "mnist_2nn": ModelSpec(
        "mnist_2nn", mlp.init, mlp.loss_and_metrics,
        "image", mlp.INPUT_DIM, 10, (10, 50), 64,
    ),
    "mnist_cnn": ModelSpec(
        "mnist_cnn", cnn.init, cnn.loss_and_metrics,
        "image", 784, 10, (10, 50), 64,
    ),
    "shakespeare_lstm": ModelSpec(
        "shakespeare_lstm",
        lstm_models.shakespeare_init,
        lstm_models.shakespeare_loss_and_metrics,
        "tokens", lstm_models.CHAR_UNROLL, lstm_models.CHAR_VOCAB, (10, 50), 32,
    ),
    "cifar_cnn": ModelSpec(
        "cifar_cnn", cifar.init, cifar.loss_and_metrics,
        "image", cifar.INPUT_DIM, 10, (50, 100), 50,
    ),
    "word_lstm": ModelSpec(
        "word_lstm",
        lstm_models.word_init,
        lstm_models.word_loss_and_metrics,
        "tokens", lstm_models.WORD_UNROLL, lstm_models.WORD_VOCAB, (8,), 16,
    ),
}


def unraveler(spec: ModelSpec):
    """(param_count, unravel_fn) for a model, built from a throwaway init."""
    params = spec.init_fn(jax.random.PRNGKey(0))
    flat, unravel = ravel_pytree(params)
    return int(flat.size), unravel


def batch_specs(spec: ModelSpec, batch: int):
    """ShapeDtypeStructs for (x, y, w) at a given batch capacity."""
    if spec.kind == "image":
        x = jax.ShapeDtypeStruct((batch, spec.x_dim), jnp.float32)
        y = jax.ShapeDtypeStruct((batch,), jnp.int32)
        w = jax.ShapeDtypeStruct((batch,), jnp.float32)
    else:
        t = spec.x_dim
        x = jax.ShapeDtypeStruct((batch, t), jnp.int32)
        y = jax.ShapeDtypeStruct((batch, t), jnp.int32)
        w = jax.ShapeDtypeStruct((batch, t), jnp.float32)
    return x, y, w


def build_entries(spec: ModelSpec):
    """name -> (fn, example_args) for everything aot.py must lower."""
    param_count, unravel = unraveler(spec)
    theta_spec = jax.ShapeDtypeStruct((param_count,), jnp.float32)
    scalar_f32 = jax.ShapeDtypeStruct((), jnp.float32)
    scalar_i32 = jax.ShapeDtypeStruct((), jnp.int32)

    def init_fn(seed):
        params = spec.init_fn(jax.random.PRNGKey(seed))
        return (ravel_pytree(params)[0],)

    def mean_loss(theta, x, y, w):
        wloss, _, wsum = spec.loss_fn(unravel(theta), x, y, w)
        return wloss / jnp.maximum(wsum, 1e-9)

    def sum_loss(theta, x, y, w):
        wloss, _, _ = spec.loss_fn(unravel(theta), x, y, w)
        return wloss

    def step_fn(theta, x, y, w, lr):
        g = jax.grad(mean_loss)(theta, x, y, w)
        return (sgd_update(theta, g, lr),)

    def gradacc_fn(theta, x, y, w):
        return (jax.grad(sum_loss)(theta, x, y, w),)

    def apply_fn(theta, g, lr):
        return (sgd_update(theta, g, lr),)

    def eval_fn(theta, x, y, w):
        wloss, wcorrect, wsum = spec.loss_fn(unravel(theta), x, y, w)
        return (jnp.stack([wloss, wcorrect, wsum]),)

    entries = {"init": (init_fn, (scalar_i32,))}
    for b in spec.step_batches:
        x, y, w = batch_specs(spec, b)
        entries[f"step_b{b}"] = (step_fn, (theta_spec, x, y, w, scalar_f32))
    xa, ya, wa = batch_specs(spec, spec.acc_batch)
    entries[f"gradacc_b{spec.acc_batch}"] = (gradacc_fn, (theta_spec, xa, ya, wa))
    entries["apply"] = (apply_fn, (theta_spec, theta_spec, scalar_f32))
    entries[f"eval_b{spec.acc_batch}"] = (eval_fn, (theta_spec, xa, ya, wa))
    return param_count, entries
