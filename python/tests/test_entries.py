"""AOT entry-point semantics: the contract the rust coordinator relies on.

These tests exercise the exact functions aot.py lowers (not the artifacts
themselves — the rust integration tests execute those) and pin down:

* ``step`` == theta - lr * grad(weighted-mean loss)
* ``gradacc`` is linear in examples  =>  chunked full-batch grads are exact
* ``apply(theta, gradacc_sum / n, lr)`` == one full-batch SGD step
* ``init`` is deterministic per seed, distinct across seeds
* ``eval`` returns (sum w*loss, sum w*correct, sum w)
"""

import jax
import jax.numpy as jnp
import numpy as np

from compile.model import MODELS, build_entries

jax.config.update("jax_platform_name", "cpu")

SPEC = MODELS["mnist_2nn"]
PC, ENTRIES = build_entries(SPEC)


def _batch(seed, n):
    k = jax.random.PRNGKey(seed)
    x = jax.random.normal(k, (n, 784))
    y = jax.random.randint(k, (n,), 0, 10).astype(jnp.int32)
    w = jnp.ones((n,))
    return x, y, w


def _theta(seed=0):
    init_fn, _ = ENTRIES["init"]
    return init_fn(jnp.int32(seed))[0]


def test_init_deterministic_and_seed_sensitive():
    init_fn, _ = ENTRIES["init"]
    a = init_fn(jnp.int32(7))[0]
    b = init_fn(jnp.int32(7))[0]
    c = init_fn(jnp.int32(8))[0]
    assert a.shape == (PC,)
    np.testing.assert_array_equal(a, b)
    assert float(jnp.abs(a - c).max()) > 0.0


def test_init_scale_reasonable():
    theta = _theta()
    # glorot-init network: weights bounded, biases zero -> modest norm
    assert 0.1 < float(jnp.linalg.norm(theta)) < 100.0
    assert float(jnp.abs(theta).max()) < 1.0


def test_step_is_sgd_on_weighted_mean_loss():
    step_fn, _ = ENTRIES["step_b10"]
    theta = _theta()
    x, y, w = _batch(1, 10)
    lr = jnp.float32(0.5)
    got = step_fn(theta, x, y, w, lr)[0]

    gradacc_fn, _ = ENTRIES["gradacc_b64"]
    xp = jnp.pad(x, ((0, 54), (0, 0)))
    yp = jnp.pad(y, (0, 54))
    wp = jnp.pad(w, (0, 54))
    g = gradacc_fn(theta, xp, yp, wp)[0] / 10.0
    np.testing.assert_allclose(got, theta - 0.5 * g, rtol=1e-4, atol=1e-6)


def test_step_ignores_padding_rows():
    step_fn, _ = ENTRIES["step_b10"]
    theta = _theta()
    x, y, w = _batch(2, 10)
    w = w.at[7:].set(0.0)
    base = step_fn(theta, x, y, w, jnp.float32(0.1))[0]
    x2 = x.at[7:].set(99.0)
    y2 = y.at[7:].set(0)
    pad = step_fn(theta, x2, y2, w, jnp.float32(0.1))[0]
    np.testing.assert_allclose(base, pad, rtol=1e-5, atol=1e-7)


def test_gradacc_linear_in_examples():
    """gradacc(A ∪ B) == gradacc(A) + gradacc(B) — the chunking identity."""
    gradacc_fn, _ = ENTRIES["gradacc_b64"]
    theta = _theta()
    x, y, w = _batch(3, 64)
    full = gradacc_fn(theta, x, y, w)[0]
    wa = w.at[32:].set(0.0)
    wb = w.at[:32].set(0.0)
    a = gradacc_fn(theta, x, y, wa)[0]
    b = gradacc_fn(theta, x, y, wb)[0]
    np.testing.assert_allclose(full, a + b, rtol=1e-4, atol=1e-6)


def test_apply_matches_axpy():
    apply_fn, _ = ENTRIES["apply"]
    theta = _theta()
    g = jax.random.normal(jax.random.PRNGKey(5), (PC,))
    out = apply_fn(theta, g, jnp.float32(0.25))[0]
    np.testing.assert_allclose(out, theta - 0.25 * g, rtol=1e-5, atol=1e-6)


def test_full_batch_step_via_gradacc_chunks_matches_big_step():
    """B=inf semantics: chunked gradacc + apply == single-shot step."""
    step_fn, _ = ENTRIES["step_b50"]
    gradacc_fn, _ = ENTRIES["gradacc_b64"]
    apply_fn, _ = ENTRIES["apply"]
    theta = _theta()
    x, y, w = _batch(6, 50)
    lr = jnp.float32(0.3)
    direct = step_fn(theta, x, y, w, lr)[0]

    # two chunks of 25 through the 64-capacity gradacc
    def chunk(lo, hi):
        n = hi - lo
        xp = jnp.pad(x[lo:hi], ((0, 64 - n), (0, 0)))
        yp = jnp.pad(y[lo:hi], (0, 64 - n))
        wp = jnp.pad(w[lo:hi], (0, 64 - n))
        return gradacc_fn(theta, xp, yp, wp)[0]

    g = (chunk(0, 25) + chunk(25, 50)) / 50.0
    via_chunks = apply_fn(theta, g, lr)[0]
    np.testing.assert_allclose(direct, via_chunks, rtol=1e-4, atol=1e-6)


def test_eval_semantics():
    eval_fn, _ = ENTRIES["eval_b64"]
    theta = _theta()
    x, y, w = _batch(8, 64)
    w = w.at[50:].set(0.0)
    out = eval_fn(theta, x, y, w)[0]
    assert out.shape == (3,)
    wloss, wcorrect, wsum = (float(v) for v in out)
    assert wsum == 50.0
    assert 0.0 <= wcorrect <= 50.0
    assert wloss > 0.0
    # random init, 10 classes: loss/example near ln(10)
    assert 1.0 < wloss / wsum < 4.0


def test_all_models_have_required_entries():
    for name, spec in MODELS.items():
        if name == "word_lstm":
            continue  # heavy; covered by artifacts-full path
        pc, entries = build_entries(spec)
        assert pc > 0
        assert "init" in entries and "apply" in entries
        assert any(e.startswith("step_b") for e in entries)
        assert any(e.startswith("gradacc_b") for e in entries)
        assert any(e.startswith("eval_b") for e in entries)
