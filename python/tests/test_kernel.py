"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (including awkward non-multiple-of-block sizes)
and both forward values and custom-VJP gradients are checked against the
reference implementations in ``compile.kernels.ref``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lstm_cell, matmul_fused, sgd_update, softmax_xent
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

ACTS = ["none", "relu", "tanh", "sigmoid"]


def _rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ----------------------------------------------------------------- matmul


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 200),
    n=st.integers(1, 150),
    act=st.sampled_from(ACTS),
    seed=st.integers(0, 2**16),
)
def test_matmul_fused_forward(m, k, n, act, seed):
    x = _rand(seed, (m, k))
    w = _rand(seed + 1, (k, n))
    b = _rand(seed + 2, (n,))
    got = matmul_fused(x, w, b, act)
    want = ref.matmul_fused_ref(x, w, b, act)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("act", ACTS)
def test_matmul_fused_grad(act):
    x = _rand(0, (9, 33))
    w = _rand(1, (33, 17))
    b = _rand(2, (17,))

    def f(fn):
        return lambda x, w, b: jnp.sum(jnp.sin(fn(x, w, b, act)))

    g1 = jax.grad(f(matmul_fused), argnums=(0, 1, 2))(x, w, b)
    g2 = jax.grad(f(ref.matmul_fused_ref), argnums=(0, 1, 2))(x, w, b)
    for a, e in zip(g1, g2):
        np.testing.assert_allclose(a, e, rtol=1e-4, atol=1e-5)


def test_matmul_mxu_sized_blocks():
    """Shapes that exactly tile the 128-edge MXU blocks (no padding path)."""
    x = _rand(0, (128, 256))
    w = _rand(1, (256, 384))
    b = _rand(2, (384,))
    np.testing.assert_allclose(
        matmul_fused(x, w, b, "relu"),
        ref.matmul_fused_ref(x, w, b, "relu"),
        rtol=1e-4,
        atol=1e-4,
    )


def test_matmul_under_jit_and_vmap_free():
    x = _rand(0, (5, 7))
    w = _rand(1, (7, 3))
    b = jnp.zeros((3,))
    jitted = jax.jit(lambda x: matmul_fused(x, w, b, "none"))
    np.testing.assert_allclose(jitted(x), x @ w, rtol=1e-5, atol=1e-6)


# ----------------------------------------------------------------- sgd axpy


@settings(max_examples=20, deadline=None)
@given(p=st.integers(1, 300_000), lr=st.floats(0.0, 10.0), seed=st.integers(0, 99))
def test_sgd_update(p, lr, seed):
    t = _rand(seed, (p,))
    g = _rand(seed + 1, (p,))
    np.testing.assert_allclose(
        sgd_update(t, g, lr), ref.sgd_update_ref(t, g, lr), rtol=1e-6, atol=1e-6
    )


def test_sgd_update_zero_lr_identity():
    t = _rand(3, (1234,))
    g = _rand(4, (1234,))
    np.testing.assert_allclose(sgd_update(t, g, 0.0), t, rtol=0, atol=0)


# ----------------------------------------------------------------- lstm cell


@settings(max_examples=20, deadline=None)
@given(b=st.integers(1, 33), h=st.integers(1, 96), seed=st.integers(0, 99))
def test_lstm_cell_forward(b, h, seed):
    z = _rand(seed, (b, 4 * h))
    c = _rand(seed + 1, (b, h))
    h1, c1 = lstm_cell(z, c)
    h2, c2 = ref.lstm_cell_ref(z, c)
    np.testing.assert_allclose(h1, h2, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(c1, c2, rtol=1e-5, atol=1e-6)


def test_lstm_cell_grad():
    z = _rand(0, (6, 64))
    c = _rand(1, (6, 16))

    def f(fn):
        return lambda z, c: jnp.sum(fn(z, c)[0] * jnp.cos(fn(z, c)[1]))

    g1 = jax.grad(f(lstm_cell), argnums=(0, 1))(z, c)
    g2 = jax.grad(f(ref.lstm_cell_ref), argnums=(0, 1))(z, c)
    for a, e in zip(g1, g2):
        np.testing.assert_allclose(a, e, rtol=1e-4, atol=1e-5)


def test_lstm_cell_forget_gate_semantics():
    """With saturated forget gate and closed input gate, c' == c."""
    h = 8
    z = jnp.concatenate(
        [
            jnp.full((2, h), -50.0),  # i -> 0
            jnp.full((2, h), 50.0),  # f -> 1
            jnp.zeros((2, h)),  # g
            jnp.zeros((2, h)),  # o
        ],
        axis=1,
    )
    c = _rand(5, (2, h))
    _, cn = lstm_cell(z, c)
    np.testing.assert_allclose(cn, c, rtol=1e-5, atol=1e-6)


# ----------------------------------------------------------------- xent


@settings(max_examples=25, deadline=None)
@given(r=st.integers(1, 80), v=st.integers(2, 300), seed=st.integers(0, 99))
def test_softmax_xent_forward(r, v, seed):
    logits = _rand(seed, (r, v), scale=3.0)
    y = jax.random.randint(jax.random.PRNGKey(seed + 7), (r,), 0, v).astype(jnp.int32)
    np.testing.assert_allclose(
        softmax_xent(logits, y),
        ref.softmax_xent_ref(logits, y),
        rtol=1e-5,
        atol=1e-5,
    )


def test_softmax_xent_padding_rows_are_zero():
    logits = _rand(0, (4, 11))
    y = jnp.array([3, -1, 5, -1], dtype=jnp.int32)
    out = softmax_xent(logits, y)
    assert out[1] == 0.0 and out[3] == 0.0
    assert out[0] > 0.0 and out[2] > 0.0


def test_softmax_xent_grad():
    logits = _rand(0, (7, 13), scale=2.0)
    y = jnp.array([0, 1, 2, -1, 4, 5, 12], dtype=jnp.int32)
    wvec = jnp.arange(7.0)

    def f(fn):
        return lambda l: jnp.sum(fn(l, y) * wvec)

    np.testing.assert_allclose(
        jax.grad(f(softmax_xent))(logits),
        jax.grad(f(ref.softmax_xent_ref))(logits),
        rtol=1e-4,
        atol=1e-5,
    )


def test_softmax_xent_numerical_stability():
    """Huge logits must not overflow (logsumexp path)."""
    logits = jnp.array([[1e4, 0.0, -1e4]], dtype=jnp.float32)
    y = jnp.array([0], dtype=jnp.int32)
    out = softmax_xent(logits, y)
    assert bool(jnp.isfinite(out[0])) and float(out[0]) < 1e-3
