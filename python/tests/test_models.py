"""L2 model zoo tests: architecture fidelity + learning sanity.

Checks the paper's exact parameter counts, output shapes, weighted-metric
semantics (padding invariance), and that a few SGD steps actually reduce
the loss for every model family.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.models import cifar, cnn, common, lstm_models, mlp

jax.config.update("jax_platform_name", "cpu")

KEY = jax.random.PRNGKey(42)


def _count(params):
    return common.count_params(params)


# ------------------------------------------------------- parameter counts


def test_mnist_2nn_param_count_matches_paper():
    assert _count(mlp.init(KEY)) == 199_210  # paper §3, exact


def test_mnist_cnn_param_count_matches_paper():
    assert _count(cnn.init(KEY)) == 1_663_370  # paper §3, exact


def test_cifar_cnn_param_count_about_1e6():
    n = _count(cifar.init(KEY))
    assert n == cifar.PARAM_COUNT and 0.9e6 < n < 1.2e6  # paper: "about 1e6"


def test_shakespeare_lstm_param_count():
    assert _count(lstm_models.shakespeare_init(KEY)) == (
        lstm_models.SHAKESPEARE_PARAM_COUNT
    )


def test_word_lstm_param_count():
    assert _count(lstm_models.word_init(KEY)) == lstm_models.WORD_PARAM_COUNT


# ------------------------------------------------------------- conv layer


def test_conv2d_matches_lax_conv():
    """im2col+Pallas path == lax.conv_general_dilated (channel-major check)."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 8, 8, 3))
    p = common.conv_params(key, 5, 5, 3, 4)
    got = common.conv2d(p, x, "none")
    w_hwio = jnp.transpose(p["w"].reshape(3, 5, 5, 4), (1, 2, 0, 3))
    want = jax.lax.conv_general_dilated(
        x, w_hwio, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    ) + p["b"]
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_maxpool2_halves_spatial():
    x = jnp.arange(32.0).reshape(1, 4, 4, 2)
    out = common.maxpool2(x)
    assert out.shape == (1, 2, 2, 2)
    assert float(out[0, 0, 0, 0]) == 10.0  # max of the top-left 2x2 window


# ------------------------------------------------- weighted-metric semantics


@pytest.mark.parametrize(
    "module,init,loss",
    [
        (mlp, mlp.init, mlp.loss_and_metrics),
        (cnn, cnn.init, cnn.loss_and_metrics),
    ],
)
def test_padding_rows_do_not_change_metrics(module, init, loss):
    params = init(KEY)
    x = jax.random.normal(KEY, (4, 784))
    y = jnp.array([1, 2, 3, 4], dtype=jnp.int32)
    w = jnp.ones((4,))
    base = loss(params, x, y, w)
    # pad with garbage rows at weight 0
    xp = jnp.concatenate([x, 100.0 * jnp.ones((3, 784))])
    yp = jnp.concatenate([y, jnp.array([0, 0, 0], dtype=jnp.int32)])
    wp = jnp.concatenate([w, jnp.zeros((3,))])
    padded = loss(params, xp, yp, wp)
    for a, b in zip(base, padded):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_lm_padding_tokens_do_not_change_metrics():
    params = lstm_models.shakespeare_init(KEY)
    t = lstm_models.CHAR_UNROLL
    x = jax.random.randint(KEY, (2, t), 0, 90).astype(jnp.int32)
    y = jax.random.randint(jax.random.PRNGKey(1), (2, t), 0, 90).astype(jnp.int32)
    w = jnp.ones((2, t))
    w = w.at[1, t // 2 :].set(0.0)  # second line half-padded
    full = lstm_models.shakespeare_loss_and_metrics(params, x, y, w)
    # scribble on the padded region; loss/acc sums must be identical
    x2 = x.at[1, t // 2 :].set(89)
    y2 = y.at[1, t // 2 :].set(0)
    pad = lstm_models.shakespeare_loss_and_metrics(params, x2, y2, w)
    # x in the padded region still feeds the LSTM state, but those states
    # only influence *weighted-out* predictions (causal unroll), so sums match.
    for a, b in zip(full, pad):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_weight_sum_reported():
    params = mlp.init(KEY)
    x = jax.random.normal(KEY, (6, 784))
    y = jnp.zeros((6,), jnp.int32)
    w = jnp.array([1.0, 1.0, 0.5, 0.0, 2.0, 1.0])
    _, _, wsum = mlp.loss_and_metrics(params, x, y, w)
    np.testing.assert_allclose(wsum, 5.5, rtol=1e-6)


# -------------------------------------------------------------- learnability


def _sgd_steps(init, loss, x, y, steps=30, lr=0.1):
    from jax.flatten_util import ravel_pytree

    params = init(KEY)
    flat, unravel = ravel_pytree(params)
    w = jnp.ones(y.shape[: (2 if y.ndim == 2 else 1)], jnp.float32)

    def mean_loss(theta):
        wl, _, ws = loss(unravel(theta), x, y, w)
        return wl / ws

    l0 = float(mean_loss(flat))
    g = jax.jit(jax.grad(mean_loss))
    for _ in range(steps):
        flat = flat - lr * g(flat)
    return l0, float(mean_loss(flat))


def test_mlp_learns():
    x = jax.random.normal(KEY, (32, 784))
    y = jax.random.randint(KEY, (32,), 0, 10).astype(jnp.int32)
    l0, l1 = _sgd_steps(mlp.init, mlp.loss_and_metrics, x, y)
    assert l1 < 0.7 * l0, (l0, l1)


def test_cnn_learns():
    x = jax.random.normal(KEY, (16, 784))
    y = jax.random.randint(KEY, (16,), 0, 10).astype(jnp.int32)
    l0, l1 = _sgd_steps(cnn.init, cnn.loss_and_metrics, x, y, steps=15)
    assert l1 < 0.8 * l0, (l0, l1)


def test_char_lstm_learns():
    t = lstm_models.CHAR_UNROLL
    x = jax.random.randint(KEY, (4, t), 0, 8).astype(jnp.int32)
    y = jnp.roll(x, -1, axis=1)  # next-char structure
    l0, l1 = _sgd_steps(
        lstm_models.shakespeare_init,
        lstm_models.shakespeare_loss_and_metrics,
        x,
        y,
        steps=15,
        lr=1.0,
    )
    assert l1 < 0.9 * l0, (l0, l1)
