"""AOT lowering path: stablehlo -> XlaComputation -> HLO text.

Checks the interchange constraints the rust loader depends on: text (not
proto) output, return_tuple wrapping, stable determinism, and manifest
bookkeeping fields.
"""

import jax
import jax.numpy as jnp

from compile.aot import to_hlo_text
from compile.model import MODELS, build_entries

jax.config.update("jax_platform_name", "cpu")


def _lower(entry="apply", model="mnist_2nn"):
    _, entries = build_entries(MODELS[model])
    fn, args = entries[entry]
    return to_hlo_text(jax.jit(fn).lower(*args))


def test_hlo_text_shape():
    text = _lower()
    assert text.startswith("HloModule"), text[:40]
    # return_tuple=True -> tuple root
    assert "ROOT" in text
    assert "tuple" in text


def test_hlo_text_deterministic():
    assert _lower() == _lower()


def test_init_entry_embeds_no_giant_constants():
    # init must *compute* params from the seed (threefry), not embed a
    # 199k-float literal — keeps artifacts small and seeds meaningful.
    text = _lower(entry="init")
    assert len(text) < 2_000_000
    assert "rng" in text.lower() or "iota" in text.lower()


def test_every_default_model_lowers_smallest_entry():
    for name in ["mnist_2nn", "mnist_cnn", "shakespeare_lstm", "cifar_cnn"]:
        text = _lower(entry="apply", model=name)
        assert text.startswith("HloModule")
