//! Grid-engine kill/resume smoke drill (engine-free) — the CI `grid-smoke`
//! job's workhorse (DESIGN.md §9).
//!
//! Runs a tiny 2×2 grid of synthetic cells. With `--kill-after N` the
//! process calls `exit(42)` the moment a cell starts while ≥ N cells are
//! already durably recorded — a real mid-grid kill, not a simulated
//! error. CI runs:
//!
//! ```bash
//! cargo run --release --example grid_smoke -- --out runs/a --workers 2 --kill-after 1  # dies
//! cargo run --release --example grid_smoke -- --out runs/a --workers 2                 # resumes
//! cargo run --release --example grid_smoke -- --out runs/b --workers 2                 # clean ref
//! diff -r runs/a/cells runs/b/cells && diff runs/a/grid-*/manifest.json runs/b/grid-*/manifest.json
//! ```
//!
//! and asserts the resumed grid's manifest and every cell artifact are
//! byte-identical to the uninterrupted run's.

use std::path::PathBuf;

use fedavg::exper::grid::{self, CellCtx, CellOutcome, CellWork, GridDef, GridOptions, Series};
use fedavg::runstate::atomic_write;
use fedavg::runtime::Engine;
use fedavg::util::args::Args;
use fedavg::Result;

struct SmokeCell {
    a: u64,
    b: u64,
    /// exit(42) when a cell starts with this many cells already
    /// recorded — the harness's kill switch, not part of the spec.
    kill_after: Option<usize>,
    cells_root: PathBuf,
}

fn recorded_cells(cells_root: &std::path::Path) -> usize {
    let Ok(rd) = std::fs::read_dir(cells_root) else {
        return 0;
    };
    rd.filter(|e| {
        e.as_ref()
            .map(|e| e.path().join("cell.json").exists())
            .unwrap_or(false)
    })
    .count()
}

impl CellWork for SmokeCell {
    fn spec(&self) -> String {
        format!("smoke a={} b={}", self.a, self.b)
    }

    fn needs_engine(&self) -> bool {
        false
    }

    fn run(&self, _engine: Option<&Engine>, ctx: &CellCtx) -> Result<CellOutcome> {
        // a little simulated work so parallel workers overlap — and so
        // that by the kill check below, earlier finishers' records have
        // durably landed on disk
        std::thread::sleep(std::time::Duration::from_millis(120));
        if let Some(k) = self.kill_after {
            if recorded_cells(&self.cells_root) >= k {
                eprintln!(
                    "smoke: {k} cell(s) recorded — killing the process mid-grid (exit 42)"
                );
                std::process::exit(42);
            }
        }
        std::fs::create_dir_all(&ctx.dir)?;
        let mut csv = String::from("round,value\n");
        let mut pts: Series = Vec::new();
        for r in 1..=8u64 {
            let v = (self.a * 1000 + self.b * 100 + r) as f64 / 7.0;
            csv.push_str(&format!("{r},{v}\n"));
            pts.push((r as f64, v));
        }
        atomic_write(&ctx.dir.join("curve.csv"), csv.as_bytes())?;
        let mut out = CellOutcome::default();
        out.put("a", self.a);
        out.put("b", self.b);
        out.put("final", pts.last().unwrap().1);
        out.curves.push(("series".into(), pts));
        Ok(out)
    }
}

fn main() -> Result<()> {
    let args = Args::from_env()?;
    args.check_known(&["out", "workers", "kill-after"])?;
    let out = args.str_or("out", "runs/grid-smoke");
    let workers = args.usize_or("workers", 2)?;
    let kill_after = match args.str_opt("kill-after") {
        Some(v) => Some(v.parse::<usize>()?),
        None => None,
    };
    let cells_root = PathBuf::from(&out).join("cells");

    let mut def = GridDef::new("smoke-2x2");
    for a in 1..=2u64 {
        for b in 1..=2u64 {
            def.cell(
                format!("smoke-a{a}-b{b}"),
                SmokeCell {
                    a,
                    b,
                    kill_after,
                    cells_root: cells_root.clone(),
                },
            );
        }
    }
    let opts = GridOptions {
        out_root: out.clone(),
        workers,
        ..Default::default()
    };
    let Some(report) = grid::run(def, None, &opts)? else {
        return Ok(());
    };
    println!("grid smoke: 2x2 complete — {} executed, {} reused", report.executed, report.cache_hits);
    for (i, o) in report.outcomes.iter().enumerate() {
        println!(
            "  cell {i}: a={} b={} final={}",
            o.get("a").unwrap_or("?"),
            o.get("b").unwrap_or("?"),
            o.get("final").unwrap_or("?")
        );
    }
    Ok(())
}
