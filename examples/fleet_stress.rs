//! Fleet stress: 10,000 heterogeneous clients through the event-queue
//! coordinator — no artifacts or training required.
//!
//! ```bash
//! cargo run --release --example fleet_stress
//! ```
//!
//! Demonstrates the coordinator subsystem on its own: a persistent
//! device fleet (log-uniform bandwidth, 8× compute spread, diurnal
//! availability), over-selection with straggler drops, and a round
//! deadline — the systems pressure the paper's synchronous protocol
//! abstracts away.

use fedavg::coordinator::{FleetConfig, FleetProfile, FleetSim};

fn main() -> fedavg::Result<()> {
    // 1. scenario: 10k mobile devices, aggregate m=500 of ⌈m·1.3⌉=650
    //    dispatched, 90-second round deadline
    let cfg = FleetConfig {
        profile: FleetProfile::Mobile,
        overselect: 0.3,
        deadline_s: Some(90.0),
        ..Default::default()
    };
    let clients = 10_000;
    let m = 500;
    let model_bytes = fedavg::comms::model_bytes(1_663_370); // MNIST CNN, ~6.7 MB
    let local_steps = 300.0; // E=5 epochs x 600/B=10 examples
    let mut sim = FleetSim::new(&cfg, clients, m, model_bytes, local_steps, 42)?;

    // 2. the fleet is genuinely heterogeneous: show the bandwidth spread
    let (mut slowest, mut fastest) = (f64::INFINITY, 0.0f64);
    for c in 0..clients {
        let up = sim.fleet().profile(c).up_bps;
        slowest = slowest.min(up);
        fastest = fastest.max(up);
    }
    println!(
        "fleet: {clients} devices, uplinks {:.0} kB/s .. {:.1} MB/s, m={m} (+30%), deadline 90s\n",
        slowest / 1e3,
        fastest / 1e6
    );

    // 3. run 100 rounds (two diurnal cycles)
    for _ in 0..100 {
        let r = sim.step();
        if r.round % 10 == 0 {
            println!(
                "round {:>3}: online {:>5}  dispatched {:>3}  aggregated {:>3}  dropped {:>3}{}  t={:>5.1}s",
                r.round,
                r.online,
                r.plan.dispatched.len(),
                r.plan.completed.len(),
                r.plan.dropped.len(),
                if r.plan.deadline_miss { "  MISS" } else { "" },
                r.plan.round_seconds,
            );
        }
    }

    // 4. totals: what over-selection + deadlines cost and bought
    let t = sim.totals();
    println!(
        "\n{} rounds: {} dispatched, {} aggregated, {} stragglers dropped ({:.1}%), {} deadline misses",
        t.rounds,
        t.fleet.dispatched,
        t.fleet.completed,
        t.fleet.dropped_stragglers,
        100.0 * t.fleet.dropped_stragglers as f64 / t.fleet.dispatched.max(1) as f64,
        t.fleet.deadline_misses,
    );
    println!(
        "communication: {:.2} GB up, {:.2} GB down ({:.2} GB wasted on dropped clients); sim {:.1} h",
        t.bytes_up as f64 / 1e9,
        t.bytes_down as f64 / 1e9,
        (t.fleet.dropped_stragglers * model_bytes) as f64 / 1e9,
        t.sim_seconds / 3600.0,
    );
    Ok(())
}
