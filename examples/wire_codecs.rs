//! Codec-pipeline walkthrough — no artifacts needed.
//!
//! Simulates a FedAvg-shaped model trajectory (sparse round-to-round
//! change, like compressed-uplink training produces), runs every
//! interesting codec pipeline over it, and prints wire bytes, compression
//! ratio, and round-trip error — then walks the delta-downlink protocol
//! (ack, patch, store eviction, dense fallback) a lagging client sees.
//!
//! ```text
//! cargo run --release --example wire_codecs
//! ```

use fedavg::comms::transport::{Transport, TransportConfig};
use fedavg::comms::wire::{registry_help, Pipeline};
use fedavg::data::rng::Rng;

fn main() -> fedavg::Result<()> {
    let dim = 199_210; // the MNIST 2NN's parameter count
    let dense = 4 * dim as u64;
    let mut rng = Rng::new(42);
    let base: Vec<f32> = (0..dim).map(|_| rng.gauss_f32() * 0.1).collect();
    // next round's model: ~2% of coordinates moved
    let mut theta = base.clone();
    for i in (0..dim).step_by(50) {
        theta[i] += rng.gauss_f32() * 0.05;
    }

    println!("codec registry:\n{}\n", registry_help());
    println!(
        "{:<22} {:>12} {:>9} {:>12}",
        "pipeline", "wire bytes", "ratio", "rms error"
    );
    for spec in [
        "dense",
        "q8",
        "q4",
        "topk:0.05",
        "topk:0.01",
        "topk:0.01|q8",
        "delta",
        "delta|q8",
    ] {
        let p = Pipeline::parse(spec)?;
        let b = p.has_delta().then_some((1u64, base.as_slice()));
        let frame = p.encode(&theta, b, &mut rng)?;
        let decoded = frame.decode(b.map(|(_, m)| m))?;
        let rms = (theta
            .iter()
            .zip(&decoded)
            .map(|(a, d)| ((a - d) as f64).powi(2))
            .sum::<f64>()
            / dim as f64)
            .sqrt();
        println!(
            "{:<22} {:>12} {:>8.1}x {:>12.2e}",
            spec,
            frame.wire_bytes(),
            dense as f64 / frame.wire_bytes() as f64,
            rms
        );
    }

    println!("\ndelta-downlink protocol (store cap 4, client lags):");
    let cfg = TransportConfig {
        up: None,
        down: Some(Pipeline::parse("delta")?),
        store_cap: 4,
    };
    let mut t = Transport::new(cfg, 1, dim, 7);
    let mut model = base;
    for round in 1..=10u64 {
        for i in (0..dim).step_by(50) {
            model[i] += 0.01 * round as f32;
        }
        t.publish(round, &model);
        // the client only checks in on rounds 1, 2, and 8+
        if !matches!(round, 1 | 2 | 8 | 9 | 10) {
            continue;
        }
        let bytes = t.downlink(0, round, &model);
        println!(
            "  round {round:>2}: downlink {:>9} bytes ({})",
            bytes,
            if bytes >= dense {
                "dense — first contact or ack aged out of the store"
            } else {
                "delta vs acked version"
            }
        );
    }
    println!(
        "\n(the same metering drives `fedavg run --codec ... --down-codec delta`\n \
         and the `fedavg comm` sweep; per-round columns land in runs/*/curve.csv)"
    );
    Ok(())
}
