//! CIFAR convergence comparison — Table 3 / Figure 4 in miniature:
//! sequential SGD (one minibatch = one communication round) vs FedSGD vs
//! FedAvg(E=5, B=50), with the paper's per-round learning-rate decays.
//!
//! ```bash
//! cargo run --release --example cifar_convergence -- --rounds 40
//! ```

use fedavg::baselines::sgd::{self, SgdConfig};
use fedavg::config::{BatchSize, FedConfig};
use fedavg::exper::cifar_fed;
use fedavg::federated::{self, ServerOptions};
use fedavg::runtime::Engine;
use fedavg::util::args::Args;

fn main() -> fedavg::Result<()> {
    let args = Args::from_env()?;
    args.check_known(&["rounds", "scale", "seed", "lr", "target"])?;
    let rounds = args.usize_or("rounds", 30)?;
    let scale = args.f64_or("scale", 0.04)?;
    let seed = args.u64_or("seed", 3)?;
    let lr = args.f64_or("lr", 0.1)?;
    let target = args.f64_or("target", 0.5)?;

    let engine = Engine::load(Engine::default_dir())?;
    let fed = cifar_fed(scale, seed);
    println!(
        "== cifar_convergence: {} clients x {} examples ==",
        fed.num_clients(),
        fed.total_examples() / fed.num_clients()
    );

    // sequential SGD baseline: B=100, each update is a "round"
    let sgd_res = sgd::run(
        &engine,
        &fed.train,
        &fed.test,
        &SgdConfig {
            model: "cifar_cnn".into(),
            batch: 100,
            lr,
            lr_decay: 0.9995,
            updates: rounds * 10,
            eval_every: rounds.max(4) / 4,
            target_accuracy: Some(target),
            seed,
        },
        Some(500),
    )?;
    println!(
        "SGD      : best acc {:.3} in {} updates; rounds to {:.0}%: {}",
        sgd_res.accuracy.best_value().unwrap_or(0.0),
        sgd_res.updates_run,
        target * 100.0,
        fmt(sgd_res.accuracy.rounds_to_target(target)),
    );

    for (name, cfg) in [
        (
            "FedSGD",
            FedConfig {
                model: "cifar_cnn".into(),
                c: 0.1,
                lr,
                lr_decay: 0.9934,
                rounds,
                target_accuracy: Some(target),
                seed,
                ..Default::default()
            }
            .fedsgd(),
        ),
        (
            "FedAvg",
            FedConfig {
                model: "cifar_cnn".into(),
                c: 0.1,
                e: 5,
                b: BatchSize::Fixed(50),
                lr,
                lr_decay: 0.99,
                rounds,
                target_accuracy: Some(target),
                seed,
                ..Default::default()
            },
        ),
    ] {
        let opts = ServerOptions {
            telemetry: Some(fedavg::telemetry::RunWriter::create_overwrite(
                "runs",
                &format!("cifar-{name}"),
            )?),
            eval_cap: Some(500),
            ..Default::default()
        };
        let res = federated::run(&engine, &fed, &cfg, opts)?;
        println!(
            "{name:<9}: best acc {:.3} in {} rounds; rounds to {:.0}%: {}",
            res.accuracy.best_value().unwrap_or(0.0),
            res.rounds_run,
            target * 100.0,
            fmt(res.accuracy.rounds_to_target(target)),
        );
    }
    Ok(())
}

fn fmt(v: Option<f64>) -> String {
    v.map(|r| format!("{r:.0}")).unwrap_or_else(|| "—".into())
}
