//! Char-LSTM on the synthetic playwright corpus — the paper's naturally
//! unbalanced, non-IID workload (clients = speaking roles).
//!
//! Reproduces the §3 observation that FedAvg's speedup over FedSGD is
//! *larger* on the natural by-role split than the balanced IID re-deal,
//! and exercises client availability (devices offline mid-round).
//!
//! ```bash
//! cargo run --release --example shakespeare_lstm -- --rounds 40
//! ```

use fedavg::config::{BatchSize, FedConfig};
use fedavg::exper::shakespeare_fed;
use fedavg::federated::{self, ServerOptions};
use fedavg::runtime::Engine;
use fedavg::util::args::Args;

fn main() -> fedavg::Result<()> {
    let args = Args::from_env()?;
    args.check_known(&["rounds", "scale", "seed", "lr", "availability"])?;
    let rounds = args.usize_or("rounds", 30)?;
    let scale = args.f64_or("scale", 0.03)?;
    let seed = args.u64_or("seed", 5)?;
    let lr = args.f64_or("lr", 1.0)?;
    let availability = args.f64_or("availability", 0.9)?;

    let engine = Engine::load(Engine::default_dir())?;
    println!("== shakespeare_lstm: roles as clients (unbalanced, non-IID) ==");

    for (tag, natural) in [("by-role", true), ("iid", false)] {
        let fed = shakespeare_fed(scale, natural, seed);
        let sizes = fed.client_sizes();
        let (min, max) = (
            sizes.iter().min().copied().unwrap_or(0),
            sizes.iter().max().copied().unwrap_or(0),
        );
        println!(
            "\n-- {tag}: {} clients, line counts {min}..{max}, {} test lines --",
            fed.num_clients(),
            fed.test.len()
        );
        for (algo, e, b) in [
            ("fedsgd", 1usize, BatchSize::Full),
            ("fedavg", 5, BatchSize::Fixed(10)),
        ] {
            let cfg = FedConfig {
                model: "shakespeare_lstm".into(),
                c: 0.1,
                e,
                b,
                lr,
                rounds,
                seed,
                ..Default::default()
            };
            let opts = ServerOptions {
                telemetry: Some(fedavg::telemetry::RunWriter::create_overwrite(
                    "runs",
                    &format!("shakespeare-{tag}-{algo}"),
                )?),
                availability: Some(availability),
                eval_cap: Some(400),
                ..Default::default()
            };
            let res = federated::run(&engine, &fed, &cfg, opts)?;
            println!(
                "   {algo:<7} final acc {:.3} (best {:.3}), {} rounds, {:.2} GB",
                res.final_accuracy(),
                res.accuracy.best_value().unwrap_or(0.0),
                res.rounds_run,
                res.comm.gigabytes()
            );
        }
    }
    println!("\ncurves in runs/shakespeare-*/curve.csv");
    Ok(())
}
