//! Quickstart: federated-train the MNIST 2NN with FedAvg in ~a minute.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Demonstrates the full public API surface: build a federated dataset,
//! configure FedAvg (Algorithm 1), run rounds, inspect the learning curve
//! and communication totals.

use fedavg::config::{BatchSize, FedConfig, Partition};
use fedavg::exper::mnist_fed;
use fedavg::federated::{self, ServerOptions};
use fedavg::runtime::Engine;

fn main() -> fedavg::Result<()> {
    // 1. runtime: load + compile the AOT artifacts (L2 JAX + L1 Pallas)
    let engine = Engine::load(Engine::default_dir())?;

    // 2. data: synthetic MNIST, 10 clients x 120 examples, IID partition
    let fed = mnist_fed(0.05, Partition::Iid, 7);
    println!(
        "dataset: {} — {} clients, {} train / {} test examples",
        fed.train.name,
        fed.num_clients(),
        fed.train.len(),
        fed.test.len()
    );

    // 3. algorithm: FedAvg with C=0.5, E=5 local epochs, B=10
    let cfg = FedConfig {
        model: "mnist_2nn".into(),
        c: 0.5,
        e: 5,
        b: BatchSize::Fixed(10),
        lr: 0.1,
        rounds: 30,
        seed: 7,
        ..Default::default()
    };

    // 4. run, with telemetry under runs/quickstart/
    let opts = ServerOptions {
        telemetry: Some(fedavg::telemetry::RunWriter::create_overwrite("runs", "quickstart")?),
        eval_cap: Some(600),
        ..Default::default()
    };
    let res = federated::run(&engine, &fed, &cfg, opts)?;

    // 5. results
    println!("\nfinal test accuracy: {:.3}", res.final_accuracy());
    println!(
        "communication: {:.1} MB up, simulated {:.0}s at 1MB/s uplinks",
        res.comm.bytes_up as f64 / 1e6,
        res.comm.sim_seconds
    );
    if let Some(r) = res.accuracy.rounds_to_target(0.7) {
        println!("rounds to 70% accuracy: {r:.1}");
    }
    Ok(())
}
