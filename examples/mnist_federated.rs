//! End-to-end validation driver (EXPERIMENTS.md §E2E): federated training
//! of the paper's MNIST CNN (1.66M parameters — its headline image
//! workload) for a few hundred rounds, FedAvg vs FedSGD on IID and
//! pathological non-IID partitions, logging full loss/accuracy curves and
//! communication totals.
//!
//! ```bash
//! cargo run --release --example mnist_federated            # scaled default
//! cargo run --release --example mnist_federated -- --rounds 300 --scale 0.1
//! ```

use fedavg::config::{BatchSize, FedConfig, Partition};
use fedavg::exper::mnist_fed;
use fedavg::federated::{self, ServerOptions};
use fedavg::runtime::Engine;
use fedavg::util::args::Args;

#[allow(clippy::disallowed_methods)] // Instant::now: demo prints its own wall time
fn main() -> fedavg::Result<()> {
    let args = Args::from_env()?;
    args.check_known(&["rounds", "scale", "seed", "eval-cap", "lr", "eval-every"])?;
    let rounds = args.usize_or("rounds", 200)?;
    let scale = args.f64_or("scale", 0.05)?;
    let seed = args.u64_or("seed", 11)?;
    let eval_cap = args.usize_or("eval-cap", 1000)?;
    let eval_every = args.usize_or("eval-every", 5)?;
    let lr = args.f64_or("lr", 0.1)?;

    let engine = Engine::load(Engine::default_dir())?;
    println!("== mnist_federated: the paper's headline workload, end to end ==");

    let variants: [(&str, Partition, usize, BatchSize); 4] = [
        ("fedavg-iid", Partition::Iid, 5, BatchSize::Fixed(10)),
        ("fedsgd-iid", Partition::Iid, 1, BatchSize::Full),
        ("fedavg-noniid", Partition::Pathological(2), 5, BatchSize::Fixed(10)),
        ("fedsgd-noniid", Partition::Pathological(2), 1, BatchSize::Full),
    ];

    let mut summaries = Vec::new();
    for (name, part, e, b) in variants {
        let fed = mnist_fed(scale, part, seed);
        let cfg = FedConfig {
            model: "mnist_cnn".into(),
            c: 0.1,
            e,
            b,
            lr,
            rounds,
            eval_every,
            track_train_loss: true,
            seed,
            ..Default::default()
        };
        println!(
            "\n-- {name}: {} clients x ~{} examples, E={e}, B={} --",
            fed.num_clients(),
            fed.total_examples() / fed.num_clients(),
            b.label()
        );
        let opts = ServerOptions {
            telemetry: Some(fedavg::telemetry::RunWriter::create_overwrite(
                "runs",
                &format!("mnist-federated-{name}"),
            )?),
            eval_cap: Some(eval_cap),
            ..Default::default()
        };
        let t0 = std::time::Instant::now();
        let res = federated::run(&engine, &fed, &cfg, opts)?;
        let stats = engine.stats();
        summaries.push(format!(
            "{name:<16} acc={:.4} best={:.4} train_loss={:.4} rounds={} steps={} comm={:.2}GB sim={:.0}s wall={:.0}s",
            res.final_accuracy(),
            res.accuracy.best_value().unwrap_or(0.0),
            res.train_loss
                .as_ref()
                .and_then(|c| c.last_value())
                .unwrap_or(f64::NAN),
            res.rounds_run,
            res.client_steps,
            res.comm.gigabytes(),
            res.comm.sim_seconds,
            t0.elapsed().as_secs_f64(),
        ));
        println!(
            "   engine totals: {} steps, {} gradaccs, {} evals, exec {:.1}s",
            stats.steps,
            stats.gradaccs,
            stats.evals,
            stats.execute_ms as f64 / 1e3
        );
    }

    println!("\n== summary (see runs/mnist-federated-*/curve.csv for curves) ==");
    for s in &summaries {
        println!("{s}");
    }
    Ok(())
}
