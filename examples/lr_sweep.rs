//! Learning-rate grid search — the paper's tuning methodology (§3):
//! multiplicative grid at resolution 10^(1/3), best rate selected by
//! rounds-to-target, with the interior-optimum sanity check.
//!
//! ```bash
//! cargo run --release --example lr_sweep -- --center 0.3 --points 5
//! ```

use fedavg::config::{BatchSize, FedConfig, Partition};
use fedavg::exper::mnist_fed;
use fedavg::federated::ServerOptions;
use fedavg::runtime::Engine;
use fedavg::sweep::{sweep_lr, LrGrid};
use fedavg::util::args::Args;

fn main() -> fedavg::Result<()> {
    let args = Args::from_env()?;
    args.check_known(&["center", "points", "rounds", "scale", "seed", "target", "model"])?;
    let center = args.f64_or("center", 0.1)?;
    let points = args.usize_or("points", 5)?;
    let rounds = args.usize_or("rounds", 20)?;
    let scale = args.f64_or("scale", 0.05)?;
    let seed = args.u64_or("seed", 9)?;
    let target = args.f64_or("target", 0.75)?;
    let model = args.str_or("model", "mnist_2nn");

    let engine = Engine::load(Engine::default_dir())?;
    let fed = mnist_fed(scale, Partition::Iid, seed);
    let base = FedConfig {
        model,
        c: 0.1,
        e: 1,
        b: BatchSize::Fixed(10),
        rounds,
        target_accuracy: Some(target),
        seed,
        ..Default::default()
    };
    let grid = LrGrid::new(center, 3, points);
    println!(
        "sweeping η over {:?} (10^(1/3) grid, paper methodology)",
        grid.values
            .iter()
            .map(|v| format!("{v:.3}"))
            .collect::<Vec<_>>()
    );

    let result = sweep_lr(&engine, &fed, &base, &grid, |_lr| ServerOptions {
        eval_cap: Some(600),
        ..Default::default()
    })?;

    println!("\n   η        rounds→{target:.0}%   final acc");
    for (lr, rtt, fin) in &result.table {
        println!(
            "   {lr:<8.4} {:<14} {fin:.4}",
            rtt.map(|r| format!("{r:.1}")).unwrap_or_else(|| "—".into())
        );
    }
    println!(
        "\nbest η = {:.4} (final acc {:.4}); optimum interior to grid: {}",
        result.best_lr,
        result.best.final_accuracy(),
        if result.interior { "yes ✓" } else { "NO — widen the grid" }
    );
    Ok(())
}
