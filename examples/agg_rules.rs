//! Aggregation-rule walkthrough — no artifacts needed.
//!
//! Simulates the server side of Algorithm 1 on a toy quadratic
//! `f(w) = ½‖w − w*‖²`: each round, honest clients report the descent
//! delta `η·(w* − w)` plus client noise, while a few corrupted clients
//! report large garbage. Every rule in the `federated::aggregate`
//! registry drives its own copy of the model; the table shows who
//! reaches the optimum and who is dragged away — and what the server
//! optimizers' internal state looks like along the way.
//!
//! ```text
//! cargo run --release --example agg_rules
//! ```

use fedavg::data::rng::Rng;
use fedavg::federated::aggregate::{fmt_state_norms, registry_help, AggConfig, Aggregator as _};
use fedavg::params;

fn main() -> fedavg::Result<()> {
    let dim = 10_000;
    let rounds = 40u64;
    let m = 20; // cohort size per round
    let corrupted = 4; // Byzantine clients per round
    let client_lr = 0.3f32;

    let mut rng = Rng::new(7);
    let target: Vec<f32> = (0..dim).map(|_| rng.gauss_f32()).collect();
    let w0: Vec<f32> = vec![0.0; dim];

    println!("aggregator registry:\n{}\n", registry_help());
    println!(
        "toy quadratic, dim {dim}: {m} clients/round, {corrupted} corrupted \
         (reporting pure noise at 100x the honest signal, with a lied-about \
         40x weight), {rounds} rounds\n"
    );
    println!(
        "{:<14} {:>12} {:>14}  {}",
        "rule", "‖w − w*‖", "vs round 0", "server state"
    );

    let start_dist = params::l2_dist(&w0, &target);
    for spec in ["fedavg", "fedavgm", "fedadam", "trimmed:0.2", "median"] {
        let cfg = AggConfig {
            spec: spec.into(),
            // Adam normalizes the step to ~η_s per coordinate; this toy
            // problem's scale wants a bit more than the 0.01 rule default
            server_lr: (spec == "fedadam").then_some(0.05),
            ..Default::default()
        };
        let mut agg = cfg.build()?;
        let mut w = w0.clone();
        let mut rng = Rng::new(99); // same client noise for every rule
        for round in 1..=rounds {
            let deltas: Vec<(f32, Vec<f32>)> = (0..m)
                .map(|k| {
                    let honest = k >= corrupted;
                    let d: Vec<f32> = w
                        .iter()
                        .zip(&target)
                        .map(|(wi, ti)| {
                            if honest {
                                client_lr * (ti - wi) + 0.05 * rng.gauss_f32()
                            } else {
                                // garbage: pure large-amplitude noise
                                100.0 * rng.gauss_f32()
                            }
                        })
                        .collect();
                    // corrupted clients also claim a huge n_k
                    (if honest { 1.0 } else { 40.0 }, d)
                })
                .collect();
            let refs: Vec<(f32, &[f32])> =
                deltas.iter().map(|(wt, d)| (*wt, d.as_slice())).collect();
            let combined = agg.combine(&refs)?;
            let step = agg.step(round, combined)?;
            params::axpy(&mut w, 1.0, &step);
        }
        let dist = params::l2_dist(&w, &target);
        println!(
            "{:<14} {:>12.4} {:>13.1}x  {}",
            agg.label(),
            dist,
            start_dist / dist.max(1e-12),
            fmt_state_norms(&agg.state_norms()),
        );
    }
    println!(
        "\nthe robust order statistics (trimmed, median) ignore both the \
         corrupted values and the lied-about weights; plain fedavg follows \
         the garbage. `fedavg agg --corrupt 0.2` runs the same comparison \
         with real training."
    );
    Ok(())
}
