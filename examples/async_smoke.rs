//! Buffered-async kill/resume smoke drill (engine-free) — the CI
//! `async-smoke` job's workhorse (DESIGN.md §12).
//!
//! One seeded buffered-async run over the uniform fleet with synthetic
//! client deltas, driving the real subsystems: sampler, virtual-clock
//! wave scheduler, transport (top-k + q8 with error feedback), stateful
//! server rule, the K-delta staleness buffer, and per-round snapshots.
//! Two determinism drills, straight from `rust/tests/async_rounds.rs`:
//!
//! * `--workers N` only scrambles the order client updates are
//!   *computed* in (the pool emulation) — the curve must be
//!   byte-identical for every N, because arrival order is the virtual
//!   clock's, not the pool's.
//! * `--kill-after R` calls `exit(42)` right after round R's checkpoint
//!   — on the uniform fleet with buffer 3 that checkpoint holds a
//!   part-full buffer, a real mid-buffer kill. Re-running the same
//!   `--out` resumes from the snapshot and must reproduce the
//!   uninterrupted curve byte-for-byte.
//!
//! ```bash
//! async_smoke --out runs/a --workers 1
//! async_smoke --out runs/b --workers 4       # same bytes
//! async_smoke --out runs/c --kill-after 2    # dies with exit 42
//! async_smoke --out runs/c                   # resumes
//! diff runs/a/smoke/curve.csv runs/b/smoke/curve.csv
//! diff runs/a/smoke/curve.csv runs/c/smoke/curve.csv
//! ```

use std::path::PathBuf;

use fedavg::comms::{CommModel, CommSim, Transport, TransportConfig};
use fedavg::coordinator::{plan_async_wave, Fleet, FleetConfig, FleetProfile, FleetTotals};
use fedavg::data::rng::hash3_unit;
use fedavg::federated::aggregate::{
    fmt_state_norms, staleness_scale, staleness_weight, AggConfig, Aggregator,
};
use fedavg::federated::ClientSampler;
use fedavg::metrics::LearningCurve;
use fedavg::params;
use fedavg::runstate::{
    checkpoint_dir, AggState, AsyncState, BufferedDelta, CurveState, FleetState, RunMeta,
    Snapshot,
};
use fedavg::telemetry::{RoundRecord, RunWriter};
use fedavg::util::args::Args;
use fedavg::Result;

const DIM: usize = 301;
const K: usize = 12;
const M: usize = 4;
const SEED: u64 = 23;
const BUFFER: usize = 3;
const DECAY: f64 = 0.8;
const STEPS: f64 = 5.0;
const EVAL_EVERY: u64 = 2;

fn synth_delta(round: u64, client: usize, theta: &[f32]) -> Vec<f32> {
    (0..DIM)
        .map(|i| {
            (hash3_unit(round, client as u64, i as u64) as f32 - 0.5) * 0.1
                - 0.01 * theta[i]
        })
        .collect()
}

fn fake_eval(theta: &[f32]) -> (f64, f64) {
    let n = params::l2_norm(theta);
    (1.0 / (1.0 + n), n)
}

struct Smoke {
    theta: Vec<f32>,
    sampler: ClientSampler,
    transport: Transport,
    comms: CommSim,
    agg: Box<dyn Aggregator>,
    fleet: Fleet,
    astate: AsyncState,
    accuracy: LearningCurve,
    test_loss: LearningCurve,
    client_steps: u64,
    scrambled_workers: bool,
    meta: RunMeta,
}

fn smoke() -> Smoke {
    let cfg = FleetConfig {
        profile: FleetProfile::Uniform,
        async_buffer: Some(BUFFER),
        staleness_decay: DECAY,
        ..FleetConfig::default()
    };
    let transport_cfg = TransportConfig::parse(Some("topk:30|q8"), Some("delta")).unwrap();
    let transport = Transport::new(transport_cfg, K, DIM, SEED);
    let agg = AggConfig { spec: "fedavgm:0.8".into(), ..Default::default() }.build().unwrap();
    let meta = RunMeta {
        label: "async smoke".into(),
        agg: agg.label(),
        codec: transport.codec_label(),
        seed: SEED,
        clients: K as u64,
        dim: DIM as u64,
        lr_decay: 1.0,
        eval_every: EVAL_EVERY,
        harness: format!("async=({BUFFER},{DECAY})"),
    };
    Smoke {
        theta: (0..DIM).map(|i| (i as f32 * 0.01).sin()).collect(),
        sampler: ClientSampler::new(SEED),
        transport,
        comms: CommSim::new(CommModel::default(), SEED),
        agg,
        fleet: Fleet::build(&cfg, K, SEED),
        astate: AsyncState::default(),
        accuracy: LearningCurve::new(),
        test_loss: LearningCurve::new(),
        client_steps: 0,
        scrambled_workers: false,
        meta,
    }
}

impl Smoke {
    /// One buffered-async wave — the same state flow as
    /// `federated::server::run`'s async branch (and the engine-free
    /// harness in `rust/tests/async_rounds.rs`).
    fn round(&mut self, round: u64, last: u64, w: &mut RunWriter) -> Result<()> {
        self.transport.publish(round, &self.theta);
        let est_up = self.transport.up_plan_bytes();
        let mut down_total = 0u64;
        let wv = {
            let Smoke { ref fleet, ref mut sampler, ref mut transport, ref theta, .. } = *self;
            let (_, wv) = plan_async_wave(
                fleet,
                sampler,
                round,
                M,
                |c| {
                    let down = transport.downlink(c, round, theta);
                    down_total += down;
                    (down, est_up)
                },
                |_| STEPS,
            );
            wv
        };
        let picks = &wv.dispatched;

        let mut slots: Vec<(usize, usize, Vec<f32>)> = Vec::new();
        let order: Vec<usize> = if self.scrambled_workers {
            (0..picks.len()).rev().collect()
        } else {
            (0..picks.len()).collect()
        };
        for slot in order {
            let ck = picks[slot];
            self.client_steps += STEPS as u64;
            slots.push((slot, ck, synth_delta(round, ck, &self.theta)));
        }
        slots.sort_by_key(|(slot, _, _)| *slot);
        let mut wire_up = 0u64;
        let mut arrived: Vec<Option<(f32, Vec<f32>)>> =
            (0..picks.len()).map(|_| None).collect();
        for (slot, ck, mut delta) in slots {
            wire_up += self.transport.encode_up(ck, &mut delta)?;
            arrived[slot] = Some(((ck % 3 + 1) as f32, delta));
        }

        let a = &mut self.astate;
        for arr in &wv.arrivals {
            let Some((weight, delta)) = arrived[arr.slot].take() else { continue };
            a.pending.push(BufferedDelta {
                dispatch_round: round,
                slot: arr.slot as u64,
                client: arr.client as u64,
                basis: a.applies_done,
                weight,
                due_s: 0.0,
                delta,
            });
        }
        while a.pending.len() >= BUFFER {
            let mut batch: Vec<BufferedDelta> = a.pending.drain(..BUFFER).collect();
            batch.sort_by_key(|e| (e.dispatch_round, e.slot));
            let stale: Vec<(f32, u64)> =
                batch.iter().map(|e| (e.weight, a.applies_done - e.basis)).collect();
            let scale = staleness_scale(&stale, DECAY);
            let mut agg_delta = if scale > 0.0 {
                let refs: Vec<(f32, &[f32])> = batch
                    .iter()
                    .zip(&stale)
                    .map(|(e, &(wt, s))| (staleness_weight(wt, DECAY, s), e.delta.as_slice()))
                    .collect();
                self.agg.combine(&refs)?
            } else {
                vec![0.0f32; self.theta.len()]
            };
            if scale != 1.0 {
                for v in agg_delta.iter_mut() {
                    *v = (*v as f64 * scale) as f32;
                }
            }
            let step = self.agg.step(a.applies_done + 1, agg_delta)?;
            params::axpy(&mut self.theta, 1.0, &step);
            a.applies_done += 1;
            a.deltas_since_eval += BUFFER as u64;
            for &(_, s) in &stale {
                a.stale_sum_since_eval += s;
            }
        }
        let rc = self.comms.ingest(wire_up, down_total, wv.round_seconds);

        if round % EVAL_EVERY == 0 || round == last {
            let (acc, loss) = fake_eval(&self.theta);
            self.accuracy.push(round, acc);
            self.test_loss.push(round, loss);
            let server_state = fmt_state_norms(&self.agg.state_norms());
            let a = &self.astate;
            w.record(&RoundRecord {
                round,
                test_accuracy: acc,
                test_loss: loss,
                train_loss: None,
                clients: picks.len(),
                lr: 0.1,
                up_bytes: rc.bytes_up,
                down_bytes: rc.bytes_down,
                codec: &self.meta.codec,
                sim_seconds: self.comms.totals().sim_seconds,
                dropped: 0,
                deadline_misses: 0,
                agg: &self.meta.agg,
                server_state: &server_state,
                staleness_mean: if a.deltas_since_eval > 0 {
                    a.stale_sum_since_eval as f64 / a.deltas_since_eval as f64
                } else {
                    0.0
                },
                buffer_fill: a.pending.len(),
            })?;
            self.astate.stale_sum_since_eval = 0;
            self.astate.deltas_since_eval = 0;
        }
        Ok(())
    }

    fn snapshot(&self, round: u64) -> Snapshot {
        Snapshot {
            round,
            meta: self.meta.clone(),
            theta: self.theta.clone(),
            client_steps: self.client_steps,
            sampler: self.sampler.state(),
            agg: AggState { label: self.agg.label(), bytes: self.agg.state_save() },
            transport: self.transport.state_save(),
            comms: self.comms.state_save(),
            fleet: FleetState {
                totals: FleetTotals::default(),
                dropped_since_eval: 0,
                misses_since_eval: 0,
            },
            curves: CurveState {
                accuracy: self.accuracy.points().to_vec(),
                test_loss: self.test_loss.points().to_vec(),
                train_loss: None,
            },
            dp: None,
            tier: None,
            async_state: Some(self.astate.clone()),
        }
    }

    fn restore(&mut self, snap: Snapshot) -> Result<()> {
        anyhow::ensure!(snap.meta == self.meta, "config fingerprint mismatch");
        self.theta = snap.theta;
        self.sampler.restore_state(snap.sampler);
        self.agg.state_load(&snap.agg.bytes)?;
        self.transport.state_load(snap.transport)?;
        self.comms.state_load(snap.comms);
        self.accuracy = LearningCurve::from_points(snap.curves.accuracy)?;
        self.test_loss = LearningCurve::from_points(snap.curves.test_loss)?;
        self.client_steps = snap.client_steps;
        self.astate = snap.async_state.expect("async smoke snapshot carries ASYNC");
        Ok(())
    }
}

fn main() -> Result<()> {
    let args = Args::from_env()?;
    args.check_known(&["out", "workers", "rounds", "kill-after"])?;
    let out = PathBuf::from(args.str_or("out", "runs/async-smoke"));
    let workers = args.usize_or("workers", 1)?;
    let rounds = args.u64_or("rounds", 10)?;
    let kill_after = match args.str_opt("kill-after") {
        Some(v) => Some(v.parse::<u64>()?),
        None => None,
    };

    let mut s = smoke();
    s.scrambled_workers = workers > 1;
    let run_dir = out.join("smoke");

    // resume if a previous (killed) invocation left checkpoints behind
    let (mut w, start) = match Snapshot::load_latest(&run_dir)? {
        Some((_, snap)) => {
            let at = snap.round;
            s.restore(snap)?;
            println!("async smoke: resuming after round {at} (applies {}, {} pending)",
                s.astate.applies_done, s.astate.pending.len());
            (RunWriter::reopen(&run_dir, at)?, at + 1)
        }
        None => (RunWriter::create(&out, "smoke")?, 1),
    };
    let ckpts = checkpoint_dir(&run_dir);
    for round in start..=rounds {
        s.round(round, rounds, &mut w)?;
        s.snapshot(round).write(&ckpts, 2)?;
        if kill_after == Some(round) {
            eprintln!(
                "async smoke: round {round} checkpointed with {} delta(s) mid-buffer — \
                 killing the process (exit 42)",
                s.astate.pending.len()
            );
            std::process::exit(42);
        }
    }
    w.finish(&[("rounds", rounds.to_string())])?;
    println!(
        "async smoke: {rounds} waves, {} buffer applies, {} delta(s) still pending, \
         mean |θ| {:.4}",
        s.astate.applies_done,
        s.astate.pending.len(),
        params::l2_norm(&s.theta) / (DIM as f64).sqrt()
    );
    Ok(())
}
